"""The provenance graph store (paper §I, §III.B.1).

AiiDA uses PostgreSQL plus a file repository; the storage backend here is
sqlite (stdlib) behind the same narrow API, with WAL journaling so that
multiple daemon workers (OS processes) can share one database file, and a
content-addressed :class:`~repro.provenance.repository.BlobRepository`
next to the database so bulk payloads (arrays, retrieved files) never
enter the ``nodes`` table. Swapping in Postgres means reimplementing the
~15 SQL statements in this file.

Graph model:
  nodes  — data values and process executions (CalcFunctionNode,
           WorkFunctionNode, WorkChainNode, CalcJobNode, DataNode …)
  links  — typed, labelled edges: INPUT_CALC/INPUT_WORK (data -> process),
           CREATE (calc -> data), RETURN (work -> data),
           CALL_CALC/CALL_WORK (workflow -> subprocess)
  logs   — the WorkChain.report() records (REPORT log level), attached to
           their emitting process node

Write model (the criterion-(v) hot path):
  * every mutating call commits on its own **unless** it runs inside a
    ``store.transaction()`` block — the engine wraps each process step
    (state transition + data storing + checkpoint) in one transaction, so
    provenance costs ~2 commits per process instead of ~12;
  * ``store_data_many`` / ``add_links`` / ``add_logs`` /
    ``insert_node_rows`` are the bulk (``executemany``) mutators;
  * payload documents whose bulk content exceeds ``inline_threshold``
    (default 4 KiB, env ``REPRO_REPO_INLINE_MAX``) are transparently
    routed to the blob repository and rehydrated on ``load_data``.

Read model:
  * ``get_nodes`` / ``links_for`` / ``logs_for`` are the batched readers
    (chunked ``IN (…)`` queries) that graph traversals use instead of
    per-node queries;
  * ``SUMMARY_COLUMNS`` is the projection hot reads use so listing or
    waiting on processes never fetches ``payload``/``checkpoint`` text.
"""

from __future__ import annotations

import base64
import contextlib
import enum
import json
import os
import sqlite3
import threading
import time
import uuid as uuid_mod
from typing import TYPE_CHECKING, Any, Iterable, Sequence

from repro.chaos import faults as chaos
from repro.observability import trace
from repro.observability.metrics import StatsDict
from repro.provenance.repository import BlobRepository

if TYPE_CHECKING:  # imported lazily at runtime (core <-> provenance cycle)
    from repro.core.datatypes import DataValue


class NodeType(str, enum.Enum):
    DATA = "data"
    CALC_FUNCTION = "process.calcfunction"
    WORK_FUNCTION = "process.workfunction"
    WORK_CHAIN = "process.workchain"
    CALC_JOB = "process.calcjob"
    PROCESS = "process.process"

    @property
    def is_process(self) -> bool:
        return self.value.startswith("process")


class LinkType(str, enum.Enum):
    INPUT_CALC = "input_calc"
    INPUT_WORK = "input_work"
    CREATE = "create"
    RETURN = "return"
    CALL_CALC = "call_calc"
    CALL_WORK = "call_work"


_SCHEMA = """
CREATE TABLE IF NOT EXISTS nodes (
    pk INTEGER PRIMARY KEY AUTOINCREMENT,
    uuid TEXT UNIQUE NOT NULL,
    node_type TEXT NOT NULL,
    process_type TEXT,
    label TEXT DEFAULT '',
    description TEXT DEFAULT '',
    attributes TEXT DEFAULT '{}',
    payload TEXT,
    process_state TEXT,
    exit_status INTEGER,
    exit_message TEXT,
    checkpoint TEXT,
    node_hash TEXT,
    ctime REAL NOT NULL,
    mtime REAL NOT NULL
);
CREATE TABLE IF NOT EXISTS links (
    pk INTEGER PRIMARY KEY AUTOINCREMENT,
    in_id INTEGER NOT NULL REFERENCES nodes(pk),
    out_id INTEGER NOT NULL REFERENCES nodes(pk),
    link_type TEXT NOT NULL,
    label TEXT NOT NULL
);
CREATE TABLE IF NOT EXISTS logs (
    pk INTEGER PRIMARY KEY AUTOINCREMENT,
    node_id INTEGER NOT NULL REFERENCES nodes(pk),
    levelname TEXT NOT NULL,
    message TEXT NOT NULL,
    time REAL NOT NULL
);
CREATE TABLE IF NOT EXISTS meta (
    key TEXT PRIMARY KEY,
    value TEXT
);
CREATE INDEX IF NOT EXISTS idx_links_in ON links(in_id);
CREATE INDEX IF NOT EXISTS idx_links_out ON links(out_id);
CREATE INDEX IF NOT EXISTS idx_nodes_type ON nodes(node_type);
CREATE INDEX IF NOT EXISTS idx_nodes_state ON nodes(process_state);
CREATE INDEX IF NOT EXISTS idx_logs_node ON logs(node_id);
"""

#: every nodes column except the two bulk-text ones (payload, checkpoint) —
#: the projection for listings, waits and traversals
SUMMARY_COLUMNS = ("pk", "uuid", "node_type", "process_type", "label",
                   "description", "attributes", "process_state",
                   "exit_status", "exit_message", "node_hash", "ctime",
                   "mtime")

_NODE_COLUMNS = frozenset(SUMMARY_COLUMNS) | {"payload", "checkpoint",
                                              "lease_epoch"}


class StaleEpochError(RuntimeError):
    """A write arrived bearing a lease epoch older than one the store has
    already accepted for that pk: the writer is a zombie whose lease
    expired and whose process was re-granted to another worker. The write
    is refused (fencing token, Kleppmann-style); the zombie must abandon
    the process without touching the store."""

    def __init__(self, pk: int, epoch: int):
        super().__init__(
            f"stale lease epoch {epoch} for pk={pk}: the store has "
            "accepted writes from a newer lease holder")
        self.pk = pk
        self.epoch = epoch

#: sqlite's default bound-variable limit is 999; stay well under it
_SQL_CHUNK = 500


def _chunks(seq: Sequence, size: int = _SQL_CHUNK):
    for i in range(0, len(seq), size):
        yield seq[i:i + size]


def _cols_sql(columns: Sequence[str] | None) -> str:
    if columns is None:
        return "*"
    unknown = set(columns) - _NODE_COLUMNS
    if unknown:
        raise ValueError(f"unknown node column(s): {sorted(unknown)}")
    return ", ".join(columns)


class ProvenanceStore:
    def __init__(self, path: str = ":memory:", *,
                 inline_threshold: int | None = None):
        self.path = path
        if inline_threshold is None:
            inline_threshold = int(
                os.environ.get("REPRO_REPO_INLINE_MAX", "4096"))
        #: payload bulk content above this many bytes goes to the blob
        #: repository instead of the nodes table
        self.inline_threshold = inline_threshold
        #: observability counters; ``commits`` is the unit-of-work metric
        #: benchmarks and CI assert on (one commit per engine step).
        #: A StatsDict behaves exactly like the old plain dict but also
        #: feeds the process-wide metrics registry (`repro stats`).
        self.stats: dict[str, int] = StatsDict("store", {"commits": 0})
        self._local = threading.local()
        self._lock = threading.RLock()
        if path != ":memory:":
            os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
            repo_root = os.path.abspath(path) + ".repo"
        else:
            repo_root = None
        self.repository = BlobRepository(repo_root)
        self._conn().executescript(_SCHEMA)
        self._migrate(self._conn())
        self._conn().commit()
        self._migrate_payloads()

    @staticmethod
    def _migrate(conn: sqlite3.Connection) -> None:
        """Bring pre-existing databases up to the current schema."""
        cols = {r[1] for r in conn.execute("PRAGMA table_info(nodes)")}
        if "node_hash" not in cols:
            conn.execute("ALTER TABLE nodes ADD COLUMN node_hash TEXT")
        if "lease_epoch" not in cols:
            # fencing-token watermark: the highest lease epoch whose
            # writes this row has accepted (NULL for data nodes and
            # processes never driven through the broker)
            conn.execute("ALTER TABLE nodes ADD COLUMN lease_epoch INTEGER")
        # created here (not in _SCHEMA) so it runs after the column exists
        conn.execute("CREATE INDEX IF NOT EXISTS idx_nodes_hash"
                     " ON nodes(process_type, node_hash)")
        # legacy profiles predate the logs index (get_logs full-scanned)
        conn.execute("CREATE INDEX IF NOT EXISTS idx_logs_node"
                     " ON logs(node_id)")

    def _migrate_payloads(self, batch_size: int = 200) -> None:
        """One-shot data migration: move legacy inline bulk payloads
        (base64 arrays/folders stored as JSON text in the nodes table)
        out to the blob repository. Idempotent — stamped in ``meta`` —
        and safe under concurrent opens: externalizing the same content
        twice yields the same digests and identical row updates. Runs in
        batches (payload text is fetched ``batch_size`` rows at a time,
        one commit each) so a huge legacy profile neither loads every
        payload into memory at once nor holds the write lock for the
        whole scan."""
        if self.get_meta("repo_version") is not None:
            return
        conn = self._conn()
        pks = [r["pk"] for r in conn.execute(
            "SELECT pk FROM nodes WHERE payload IS NOT NULL"
            " AND length(payload) > ?", (self.inline_threshold,))]
        for chunk in _chunks(pks, batch_size):
            marks = ",".join("?" * len(chunk))
            rows = conn.execute(
                f"SELECT pk, payload FROM nodes WHERE pk IN ({marks})",
                chunk).fetchall()
            with self.transaction():
                for row in rows:
                    try:
                        doc = json.loads(row["payload"])
                    except ValueError:
                        continue
                    ext = self._externalize_payload(doc)
                    if ext is not doc:
                        conn.execute(
                            "UPDATE nodes SET payload=? WHERE pk=?",
                            (json.dumps(ext), row["pk"]))
        self.set_meta("repo_version", "1")

    # -- connection handling (per-thread) -------------------------------------
    def _conn(self) -> sqlite3.Connection:
        conn = getattr(self._local, "conn", None)
        if conn is None:
            conn = sqlite3.connect(self.path, timeout=30.0)
            conn.row_factory = sqlite3.Row
            conn.execute("PRAGMA journal_mode=WAL")
            conn.execute("PRAGMA busy_timeout=30000")
            conn.execute("PRAGMA synchronous=NORMAL")
            # hot-path tuning: a 16 MB page cache and a larger WAL before
            # auto-checkpointing shave ~15% off commit latency (the
            # checkpoint fsync amortizes over more commits)
            conn.execute("PRAGMA cache_size=-16000")
            conn.execute("PRAGMA wal_autocheckpoint=4000")
            self._local.conn = conn
        return conn

    def close(self) -> None:
        conn = getattr(self._local, "conn", None)
        if conn is not None:
            conn.close()
            self._local.conn = None

    # -- batched writes ---------------------------------------------------------
    @contextlib.contextmanager
    def transaction(self):
        """Group many mutating calls into one atomic commit — the engine's
        unit of work (one commit per process step) and the archive-import
        envelope. Inside the block the per-call commits become no-ops; the
        lock is held throughout, and an exception rolls everything back
        (running any ``on_rollback`` hooks, e.g. un-assigning pks handed
        out for rows that never became durable). ``after_commit`` hooks
        run once the commit lands and the lock is released — that is how
        terminal-state broadcasts stay *after* the durable write."""
        with self._lock:
            if getattr(self._local, "in_txn", False):
                yield  # nested: the outermost frame owns the commit
                return
            self._local.in_txn = True
            try:
                yield
                # crash seam: everything this unit of work wrote is still
                # un-fsynced here — dying now must lose the whole step,
                # never half of it. Inside the try so an injected *raise*
                # takes the rollback path like any mid-transaction failure.
                chaos.fault_point("store.commit.pre")
                with trace.span("store.commit"):
                    self._conn().commit()
                self.stats["commits"] += 1
            except BaseException:
                self._conn().rollback()
                for fn in getattr(self._local, "rollback_cbs", []):
                    try:
                        fn()
                    except Exception:  # noqa: BLE001 — cleanup best effort
                        pass
                self._local.post_commit = []
                self._local.rollback_cbs = []
                raise
            finally:
                self._local.in_txn = False
        # outside the lock: observers woken by these callbacks may read
        # the store from other threads/processes immediately
        callbacks = getattr(self._local, "post_commit", [])
        self._local.post_commit = []
        self._local.rollback_cbs = []
        for fn in callbacks:
            fn()
        # durable, observers notified — but the caller has not continued
        chaos.fault_point("store.commit.post")

    def after_commit(self, fn) -> None:
        """Run ``fn`` after the enclosing transaction commits; immediately
        when no transaction is open (the write is already durable)."""
        if getattr(self._local, "in_txn", False):
            if not hasattr(self._local, "post_commit"):
                self._local.post_commit = []
            self._local.post_commit.append(fn)
        else:
            fn()

    def on_rollback(self, fn) -> None:
        """Register cleanup to run if the enclosing transaction rolls
        back; a no-op when no transaction is open (nothing to undo)."""
        if getattr(self._local, "in_txn", False):
            if not hasattr(self._local, "rollback_cbs"):
                self._local.rollback_cbs = []
            self._local.rollback_cbs.append(fn)

    def _commit(self) -> None:
        if not getattr(self._local, "in_txn", False):
            try:
                chaos.fault_point("store.commit.pre")
                with trace.span("store.commit"):
                    self._conn().commit()
                self.stats["commits"] += 1
            except BaseException:
                # an injected (or real) failure must not leave the write
                # pending on the connection — the unit of work dies whole
                self._conn().rollback()
                raise
            chaos.fault_point("store.commit.post")

    # -- payload routing (blob repository) --------------------------------------
    def _externalize_payload(self, doc: Any) -> Any:
        """Route bulk content of a payload document to the repository.
        Returns a *new* dict when anything moved, the same object when
        the document stays inline (identity is the changed signal)."""
        if not isinstance(doc, dict):
            return doc
        limit = self.inline_threshold
        if doc.get("type") == "array" and "npy_b64" in doc:
            # b64 length * 3/4 is the decoded size; avoid decoding to test
            if len(doc["npy_b64"]) * 3 // 4 > limit:
                raw = base64.b64decode(doc["npy_b64"])
                return {"type": "array", "blob": self.repository.put(raw)}
        elif doc.get("type") == "folder" and doc.get("files"):
            inline: dict[str, str] = {}
            blobs: dict[str, str] = dict(doc.get("blobs") or {})
            moved = False
            for name, b64 in doc["files"].items():
                if len(b64) * 3 // 4 > limit:
                    blobs[name] = self.repository.put(base64.b64decode(b64))
                    moved = True
                else:
                    inline[name] = b64
            if moved:
                return {"type": "folder", "files": inline, "blobs": blobs}
        return doc

    def materialize_payload(self, doc: Any) -> Any:
        """Resolve repository references back to the inline payload form
        that :meth:`DataValue.from_payload` understands."""
        if not isinstance(doc, dict):
            return doc
        if doc.get("type") == "array" and "blob" in doc:
            raw = self.repository.get(doc["blob"])
            return {"type": "array",
                    "npy_b64": base64.b64encode(raw).decode()}
        if doc.get("type") == "folder" and doc.get("blobs"):
            files = dict(doc.get("files") or {})
            for name, digest in doc["blobs"].items():
                files[name] = base64.b64encode(
                    self.repository.get(digest)).decode()
            return {"type": "folder", "files": files}
        return doc

    @staticmethod
    def _unassign_on_rollback(values: "list[DataValue]"):
        """Rollback hook: a DataValue must not keep a pk whose row was
        rolled back — a later store would silently skip re-storing it and
        links would point at nonexistent rows."""
        def _undo():
            for value in values:
                value.pk = None
                value.uuid = None
        return _undo

    def _pks_by_uuid(self, uuids: Sequence[str]) -> dict[str, int]:
        pk_of: dict[str, int] = {}
        conn = self._conn()
        for chunk in _chunks(uuids):
            marks = ",".join("?" * len(chunk))
            for r in conn.execute(
                    f"SELECT pk, uuid FROM nodes WHERE uuid IN ({marks})",
                    chunk):
                pk_of[r["uuid"]] = r["pk"]
        return pk_of

    # -- node creation -----------------------------------------------------------
    def store_data(self, value: "DataValue", label: str = "") -> "DataValue":
        """Persist a DataValue; idempotent if already stored."""
        if value.is_stored:
            return value
        now = time.time()
        u = str(uuid_mod.uuid4())
        payload = json.dumps(self._externalize_payload(value.to_payload()))
        with self._lock:
            cur = self._conn().execute(
                "INSERT INTO nodes (uuid, node_type, label, payload, ctime,"
                " mtime) VALUES (?,?,?,?,?,?)",
                (u, NodeType.DATA.value, label, payload, now, now))
            self._commit()
        value.pk = cur.lastrowid
        value.uuid = u
        self.on_rollback(self._unassign_on_rollback([value]))
        return value

    def store_data_many(self, values: Iterable["DataValue"], label: str = ""
                        ) -> list["DataValue"]:
        """Bulk ``store_data``: one executemany + one commit for the whole
        batch. Already-stored values (and repeated occurrences of the same
        object) are skipped, matching sequential ``store_data`` calls."""
        values = list(values)
        now = time.time()
        rows: list[tuple] = []
        fresh: list[tuple["DataValue", str]] = []
        seen_objs: set[int] = set()
        for value in values:
            if value.is_stored or id(value) in seen_objs:
                continue
            seen_objs.add(id(value))
            u = str(uuid_mod.uuid4())
            payload = json.dumps(
                self._externalize_payload(value.to_payload()))
            rows.append((u, NodeType.DATA.value, label, payload, now, now))
            fresh.append((value, u))
        if not rows:
            return values
        with self.transaction():
            self._conn().executemany(
                "INSERT INTO nodes (uuid, node_type, label, payload, ctime,"
                " mtime) VALUES (?,?,?,?,?,?)", rows)
            pk_of = self._pks_by_uuid([u for _v, u in fresh])
        for value, u in fresh:
            value.pk = pk_of[u]
            value.uuid = u
        self.on_rollback(
            self._unassign_on_rollback([v for v, _u in fresh]))
        return values

    def create_process_node(self, node_type: NodeType, process_type: str,
                            label: str = "", description: str = "",
                            attributes: dict | None = None,
                            node_hash: str | None = None) -> int:
        now = time.time()
        u = str(uuid_mod.uuid4())
        with self._lock:
            cur = self._conn().execute(
                "INSERT INTO nodes (uuid, node_type, process_type, label,"
                " description, attributes, process_state, node_hash, ctime,"
                " mtime) VALUES (?,?,?,?,?,?,?,?,?,?)",
                (u, node_type.value, process_type, label, description,
                 json.dumps(attributes or {}), "created", node_hash, now,
                 now))
            self._commit()
        return cur.lastrowid

    # -- node updates ----------------------------------------------------------
    def update_process(self, pk: int, *, state: str | None = None,
                       exit_status: int | None = None,
                       exit_message: str | None = None,
                       attributes: dict | None = None) -> None:
        sets, vals = ["mtime=?"], [time.time()]
        if state is not None:
            sets.append("process_state=?")
            vals.append(state)
        if exit_status is not None:
            sets.append("exit_status=?")
            vals.append(exit_status)
        if exit_message is not None:
            sets.append("exit_message=?")
            vals.append(exit_message)
        if attributes is not None:
            # merge, don't replace — e.g. `cached_from` (and the durable
            # `kill_requested` control marker) must survive the
            # state-transition attribute writes. Merge in SQL, in the same
            # statement as the other column writes: a python
            # read-modify-write would race against writers in OTHER OS
            # processes (daemon workers vs a control CLI) and lose keys.
            # NB json_patch treats a null value as key deletion; no
            # caller stores None attribute values.
            sets.append("attributes="
                        "json_patch(COALESCE(attributes,'{}'),?)")
            vals.append(json.dumps(attributes))
        vals.append(pk)
        with self._lock:
            try:
                self._conn().execute(
                    f"UPDATE nodes SET {', '.join(sets)} WHERE pk=?", vals)
            except sqlite3.OperationalError:
                if attributes is None:
                    raise
                # sqlite built without JSON1: best-effort python merge
                row = self._conn().execute(
                    "SELECT attributes FROM nodes WHERE pk=?",
                    (pk,)).fetchone()
                merged = (json.loads(row["attributes"] or "{}")
                          if row else {})
                merged.update(attributes)
                sets[-1] = "attributes=?"
                vals[-2] = json.dumps(merged)
                self._conn().execute(
                    f"UPDATE nodes SET {', '.join(sets)} WHERE pk=?", vals)
            self._commit()

    # -- lease fencing (split-brain protection) --------------------------------
    def fence_epoch(self, pk: int, epoch: int | None) -> None:
        """Record that writes for ``pk`` now happen under lease ``epoch``,
        refusing the call with :class:`StaleEpochError` if the store has
        already accepted a newer epoch. A no-op for ``epoch=None`` (local,
        broker-less runs pay nothing).

        The check is an UPDATE, not a SELECT: it takes sqlite's write
        lock, so two workers racing to fence the same pk from different
        OS processes serialize here and exactly one of them loses.
        Called inside a ``transaction()`` block it joins that unit of
        work (a fenced flush rolls back whole); standalone it commits."""
        if epoch is None:
            return
        with self._lock:
            cur = self._conn().execute(
                "UPDATE nodes SET lease_epoch=? WHERE pk=?"
                " AND COALESCE(lease_epoch, 0) <= ?", (epoch, pk, epoch))
            if cur.rowcount == 0:
                exists = self._conn().execute(
                    "SELECT 1 FROM nodes WHERE pk=?", (pk,)).fetchone()
                if exists is None:
                    raise KeyError(f"no node with pk={pk}")
                raise StaleEpochError(pk, epoch)
            self._commit()

    # -- store-level counters/metadata (telemetry, e.g. hash collisions) -------
    def incr_meta(self, key: str, by: int = 1) -> int:
        """Atomically increment a store-level integer counter; returns the
        new value. Safe across OS processes (single UPSERT statement)."""
        with self._lock:
            self._conn().execute(
                "INSERT INTO meta (key, value) VALUES (?, ?)"
                " ON CONFLICT(key) DO UPDATE SET"
                " value = CAST(CAST(value AS INTEGER) + ? AS TEXT)",
                (key, str(by), by))
            self._commit()
            row = self._conn().execute(
                "SELECT value FROM meta WHERE key=?", (key,)).fetchone()
        return int(row["value"])

    def get_meta(self, key: str, default: Any = None) -> Any:
        row = self._conn().execute(
            "SELECT value FROM meta WHERE key=?", (key,)).fetchone()
        return row["value"] if row is not None else default

    def set_meta(self, key: str, value: str) -> None:
        with self._lock:
            self._conn().execute(
                "INSERT INTO meta (key, value) VALUES (?, ?)"
                " ON CONFLICT(key) DO UPDATE SET value=excluded.value",
                (key, str(value)))
            self._commit()

    def all_meta(self, prefix: str = "") -> dict[str, str]:
        rows = self._conn().execute(
            "SELECT key, value FROM meta WHERE key LIKE ?"
            " ORDER BY key", (prefix + "%",)).fetchall()
        return {r["key"]: r["value"] for r in rows}

    def set_node_hash(self, pk: int, node_hash: str | None) -> None:
        with self._lock:
            self._conn().execute(
                "UPDATE nodes SET node_hash=?, mtime=? WHERE pk=?",
                (node_hash, time.time(), pk))
            self._commit()

    def save_checkpoint(self, pk: int, checkpoint: dict | str) -> None:
        """Persist a checkpoint; accepts the dict or its pre-serialized
        JSON text (the engine serializes once for its dirty-flag check)."""
        if not isinstance(checkpoint, str):
            checkpoint = json.dumps(checkpoint)
        with self._lock:
            self._conn().execute(
                "UPDATE nodes SET checkpoint=?, mtime=? WHERE pk=?",
                (checkpoint, time.time(), pk))
            self._commit()

    def load_checkpoint(self, pk: int) -> dict | None:
        row = self._conn().execute(
            "SELECT checkpoint FROM nodes WHERE pk=?", (pk,)).fetchone()
        if row is None or row["checkpoint"] is None:
            return None
        return json.loads(row["checkpoint"])

    def delete_checkpoint(self, pk: int) -> None:
        with self._lock:
            self._conn().execute(
                "UPDATE nodes SET checkpoint=NULL WHERE pk=?", (pk,))
            self._commit()

    # -- bulk insertion (archive import) ---------------------------------------
    def insert_node_row(self, record: dict) -> int:
        """Insert a complete node row (archive import path): the caller
        supplies the uuid and timestamps, so identity and history survive
        the trip between profiles. Returns the assigned pk."""
        return self.insert_node_rows([record])[0]

    def insert_node_rows(self, records: Sequence[dict]) -> list[int]:
        """Bulk ``insert_node_row``: one executemany + one commit.
        ``payload`` may be a document (dict) or pre-serialized JSON text;
        either way bulk content above the inline threshold is routed to
        the blob repository. Returns the assigned pks, in input order."""
        now = time.time()
        rows: list[tuple] = []
        uuids: list[str] = []
        for record in records:
            payload = record.get("payload")
            if isinstance(payload, dict):
                payload = json.dumps(self._externalize_payload(payload),
                                     sort_keys=True, separators=(",", ":"))
            elif isinstance(payload, str) and \
                    len(payload) > self.inline_threshold:
                try:
                    doc = json.loads(payload)
                except ValueError:
                    doc = None
                if isinstance(doc, dict):
                    ext = self._externalize_payload(doc)
                    if ext is not doc:
                        payload = json.dumps(ext, sort_keys=True,
                                             separators=(",", ":"))
            uuids.append(record["uuid"])
            rows.append((record["uuid"], record["node_type"],
                         record.get("process_type"),
                         record.get("label", ""),
                         record.get("description", ""),
                         json.dumps(record.get("attributes") or {}),
                         payload, record.get("process_state"),
                         record.get("exit_status"),
                         record.get("exit_message"),
                         record.get("node_hash"),
                         record.get("ctime", now),
                         record.get("mtime", now)))
        if not rows:
            return []
        with self.transaction():
            self._conn().executemany(
                "INSERT INTO nodes (uuid, node_type, process_type, label,"
                " description, attributes, payload, process_state,"
                " exit_status, exit_message, node_hash, ctime, mtime)"
                " VALUES (?,?,?,?,?,?,?,?,?,?,?,?,?)", rows)
            pk_of = self._pks_by_uuid(uuids)
        return [pk_of[u] for u in uuids]

    def get_node_by_uuid(self, uuid: str) -> dict | None:
        row = self._conn().execute(
            "SELECT * FROM nodes WHERE uuid=?", (uuid,)).fetchone()
        return dict(row) if row else None

    # -- links -------------------------------------------------------------------
    def add_link(self, in_pk: int, out_pk: int, link_type: LinkType,
                 label: str) -> None:
        with self._lock:
            self._conn().execute(
                "INSERT INTO links (in_id, out_id, link_type, label)"
                " VALUES (?,?,?,?)", (in_pk, out_pk, link_type.value, label))
            self._commit()

    def add_links(self, rows: Iterable[tuple[int, int, "LinkType | str",
                                             str]]) -> None:
        """Bulk ``add_link``: one executemany + one commit."""
        data = [(in_pk, out_pk,
                 lt.value if isinstance(lt, LinkType) else lt, label)
                for in_pk, out_pk, lt, label in rows]
        if not data:
            return
        with self.transaction():
            self._conn().executemany(
                "INSERT INTO links (in_id, out_id, link_type, label)"
                " VALUES (?,?,?,?)", data)

    def has_link(self, in_pk: int, out_pk: int, link_type: LinkType,
                 label: str) -> bool:
        row = self._conn().execute(
            "SELECT 1 FROM links WHERE in_id=? AND out_id=? AND link_type=?"
            " AND label=? LIMIT 1",
            (in_pk, out_pk, link_type.value, label)).fetchone()
        return row is not None

    def delete_outgoing_links(self, in_pk: int,
                              link_types: Iterable[LinkType]) -> None:
        """Remove typed edges leaving a node (cache-clone rollback)."""
        types = [lt.value for lt in link_types]
        marks = ",".join("?" * len(types))
        with self._lock:
            self._conn().execute(
                f"DELETE FROM links WHERE in_id=? AND link_type IN ({marks})",
                [in_pk, *types])
            self._commit()

    # -- logs ----------------------------------------------------------------------
    def add_log(self, node_pk: int, levelname: str, message: str,
                ts: float | None = None) -> None:
        """Attach a log record; ``ts`` overrides the wall clock so imported
        logs keep their original emission time."""
        with self._lock:
            self._conn().execute(
                "INSERT INTO logs (node_id, levelname, message, time)"
                " VALUES (?,?,?,?)",
                (node_pk, levelname, message,
                 time.time() if ts is None else ts))
            self._commit()

    def add_logs(self, rows: Iterable[tuple[int, str, str, float]]) -> None:
        """Bulk ``add_log``: (node_pk, levelname, message, ts) tuples,
        one executemany + one commit."""
        data = list(rows)
        if not data:
            return
        with self.transaction():
            self._conn().executemany(
                "INSERT INTO logs (node_id, levelname, message, time)"
                " VALUES (?,?,?,?)", data)

    def get_logs(self, node_pk: int) -> list[dict]:
        rows = self._conn().execute(
            "SELECT levelname, message, time FROM logs WHERE node_id=?"
            " ORDER BY pk", (node_pk,)).fetchall()
        return [dict(r) for r in rows]

    def logs_for(self, pks: Iterable[int]) -> dict[int, list[dict]]:
        """Batched ``get_logs`` over many nodes (chunked IN queries);
        returns {node_pk: [log, …]} with each list in emission order."""
        pks = [int(p) for p in pks]
        acc: list[tuple[int, int, dict]] = []
        conn = self._conn()
        for chunk in _chunks(pks):
            marks = ",".join("?" * len(chunk))
            for r in conn.execute(
                    "SELECT pk, node_id, levelname, message, time FROM logs"
                    f" WHERE node_id IN ({marks})", chunk):
                acc.append((r["node_id"], r["pk"],
                            {"levelname": r["levelname"],
                             "message": r["message"], "time": r["time"]}))
        acc.sort(key=lambda t: t[1])
        out: dict[int, list[dict]] = {}
        for node_id, _log_pk, entry in acc:
            out.setdefault(node_id, []).append(entry)
        return out

    # -- reads -----------------------------------------------------------------------
    def get_node(self, pk: int, columns: Sequence[str] | None = None
                 ) -> dict | None:
        """One node row; pass ``columns`` (e.g. ``SUMMARY_COLUMNS``) to
        skip the bulk ``payload``/``checkpoint`` text on hot reads."""
        row = self._conn().execute(
            f"SELECT {_cols_sql(columns)} FROM nodes WHERE pk=?",
            (pk,)).fetchone()
        return dict(row) if row else None

    def get_nodes(self, pks: Iterable[int],
                  columns: Sequence[str] | None = None) -> dict[int, dict]:
        """Batched ``get_node`` (chunked IN queries) -> {pk: row}.
        Missing pks are simply absent from the result. ``columns`` must
        include ``pk`` when given (it keys the result)."""
        pks = [int(p) for p in pks]
        if columns is not None and "pk" not in columns:
            columns = ("pk", *columns)
        cols = _cols_sql(columns)
        out: dict[int, dict] = {}
        conn = self._conn()
        for chunk in _chunks(pks):
            marks = ",".join("?" * len(chunk))
            for r in conn.execute(
                    f"SELECT {cols} FROM nodes WHERE pk IN ({marks})",
                    chunk):
                d = dict(r)
                out[d["pk"]] = d
        return out

    def load_data(self, pk: int) -> "DataValue":
        from repro.core.datatypes import DataValue

        node = self.get_node(pk)
        if node is None or node["node_type"] != NodeType.DATA.value:
            raise KeyError(f"no data node with pk={pk}")
        doc = self.materialize_payload(json.loads(node["payload"]))
        value = DataValue.from_payload(doc)
        value.pk = pk
        value.uuid = node["uuid"]
        return value

    def incoming(self, pk: int, link_type: LinkType | None = None
                 ) -> list[tuple[int, str, str]]:
        q = "SELECT in_id, link_type, label FROM links WHERE out_id=?"
        args: list[Any] = [pk]
        if link_type:
            q += " AND link_type=?"
            args.append(link_type.value)
        return [(r["in_id"], r["link_type"], r["label"])
                for r in self._conn().execute(q, args)]

    def outgoing(self, pk: int, link_type: LinkType | None = None
                 ) -> list[tuple[int, str, str]]:
        q = "SELECT out_id, link_type, label FROM links WHERE in_id=?"
        args: list[Any] = [pk]
        if link_type:
            q += " AND link_type=?"
            args.append(link_type.value)
        return [(r["out_id"], r["link_type"], r["label"])
                for r in self._conn().execute(q, args)]

    def links_for(self, pks: Iterable[int], direction: str = "both"
                  ) -> list[tuple[int, int, str, str]]:
        """Every link touching the given nodes, as (in_id, out_id, type,
        label) tuples — the batched traversal primitive that replaces
        per-node ``incoming``/``outgoing`` calls. ``direction`` is
        ``"in"`` (links *into* the pks), ``"out"`` (links *out of* them)
        or ``"both"``; each link appears once even when both endpoints
        are in the selection."""
        if direction not in ("in", "out", "both"):
            raise ValueError(f"bad direction {direction!r}")
        pks = list({int(p) for p in pks})
        match_cols = {"in": ("out_id",), "out": ("in_id",),
                      "both": ("in_id", "out_id")}[direction]
        seen: dict[int, tuple[int, int, str, str]] = {}
        conn = self._conn()
        for col in match_cols:
            for chunk in _chunks(pks):
                marks = ",".join("?" * len(chunk))
                for r in conn.execute(
                        "SELECT pk, in_id, out_id, link_type, label"
                        f" FROM links WHERE {col} IN ({marks})", chunk):
                    seen[r["pk"]] = (r["in_id"], r["out_id"],
                                     r["link_type"], r["label"])
        return [seen[k] for k in sorted(seen)]

    def count_nodes(self, node_type: NodeType | None = None) -> int:
        if node_type is None:
            return self._conn().execute(
                "SELECT COUNT(*) c FROM nodes").fetchone()["c"]
        return self._conn().execute(
            "SELECT COUNT(*) c FROM nodes WHERE node_type=?",
            (node_type.value,)).fetchone()["c"]

    def count_links(self) -> int:
        return self._conn().execute(
            "SELECT COUNT(*) c FROM links").fetchone()["c"]

    def unfinished_processes(self) -> list[dict]:
        rows = self._conn().execute(
            f"SELECT {', '.join(SUMMARY_COLUMNS)} FROM nodes"
            " WHERE node_type LIKE 'process%' AND"
            " process_state NOT IN ('finished','excepted','killed')"
        ).fetchall()
        return [dict(r) for r in rows]


class QueryBuilder:
    """Minimal, composable query interface over the provenance graph —
    the criterion-(iv) 'easily queryable' surface."""

    def __init__(self, store: ProvenanceStore):
        self.store = store
        self._wheres: list[str] = []
        self._args: list[Any] = []
        self._order = "pk"
        self._limit: int | None = None
        self._cols: tuple[str, ...] | None = None

    def nodes(self, node_type: NodeType | str | None = None) -> "QueryBuilder":
        if node_type is not None:
            t = node_type.value if isinstance(node_type, NodeType) else node_type
            self._wheres.append("node_type LIKE ?")
            self._args.append(f"{t}%")
        return self

    def with_node_types(self, node_types: Iterable[NodeType | str]
                        ) -> "QueryBuilder":
        """Exact node-type membership (no prefix matching)."""
        types = [t.value if isinstance(t, NodeType) else t
                 for t in node_types]
        marks = ",".join("?" * len(types))
        self._wheres.append(f"node_type IN ({marks})")
        self._args.extend(types)
        return self

    def with_null_hash(self) -> "QueryBuilder":
        """Nodes with no input fingerprint (legacy / invalidated)."""
        self._wheres.append("node_hash IS NULL")
        return self

    def with_process_type(self, process_type: str) -> "QueryBuilder":
        self._wheres.append("process_type=?")
        self._args.append(process_type)
        return self

    def with_hash(self, node_hash: str) -> "QueryBuilder":
        self._wheres.append("node_hash=?")
        self._args.append(node_hash)
        return self

    def with_state(self, state: str) -> "QueryBuilder":
        self._wheres.append("process_state=?")
        self._args.append(state)
        return self

    def with_exit_status(self, status: int) -> "QueryBuilder":
        self._wheres.append("exit_status=?")
        self._args.append(status)
        return self

    def with_label(self, label: str) -> "QueryBuilder":
        self._wheres.append("label=?")
        self._args.append(label)
        return self

    def created_after(self, ts: float) -> "QueryBuilder":
        self._wheres.append("ctime>=?")
        self._args.append(ts)
        return self

    def order_by(self, field: str, desc: bool = False) -> "QueryBuilder":
        assert field in ("pk", "ctime", "mtime")
        self._order = field + (" DESC" if desc else "")
        return self

    def limit(self, n: int) -> "QueryBuilder":
        self._limit = n
        return self

    def project(self, *columns: str) -> "QueryBuilder":
        """Fetch only these columns (``pk`` is always included) — hot
        listings skip the bulk ``payload``/``checkpoint`` text."""
        if not columns:
            raise ValueError("project() needs at least one column")
        cols = columns if "pk" in columns else ("pk", *columns)
        _cols_sql(cols)  # validate names
        self._cols = cols
        return self

    def all(self) -> list[dict]:
        q = f"SELECT {_cols_sql(self._cols)} FROM nodes"
        if self._wheres:
            q += " WHERE " + " AND ".join(self._wheres)
        q += f" ORDER BY {self._order}"
        # `is not None`, not truthiness: limit(0) means "no rows", not
        # "no limit"
        if self._limit is not None:
            q += f" LIMIT {int(self._limit)}"
        return [dict(r) for r in self.store._conn().execute(q, self._args)]

    def count(self) -> int:
        q = "SELECT COUNT(*) c FROM nodes"
        if self._wheres:
            q += " WHERE " + " AND ".join(self._wheres)
        return self.store._conn().execute(q, self._args).fetchone()["c"]

    def first(self) -> dict | None:
        """The first matching row (or None) — does not clobber a limit
        set earlier on this builder."""
        saved = self._limit
        try:
            self._limit = 1
            res = self.all()
        finally:
            self._limit = saved
        return res[0] if res else None


# ---------------------------------------------------------------------------
# Global store configuration (one per python instance, like AiiDA profiles)
# ---------------------------------------------------------------------------

_STORE: ProvenanceStore | None = None


def configure_store(path: str = ":memory:") -> ProvenanceStore:
    global _STORE
    _STORE = ProvenanceStore(path)
    return _STORE


def current_store() -> ProvenanceStore:
    global _STORE
    if _STORE is None:
        _STORE = ProvenanceStore(":memory:")
    return _STORE
