"""Serving steps and the continuous-batching request scheduler.

``make_prefill_step`` / ``make_decode_step`` are the single-program
building blocks (also lowered by the dry-run for ``decode_*`` cells).
:class:`BatchScheduler` composes them into request-level micro-batching:

* **admission** — FIFO queue; a free slot triggers a one-row prefill of
  the request's exact prompt (no padding, so the first sampled token is
  taken at the true last prompt position) whose KV rows are spliced into
  the slot's row of the shared batch cache;
* **per-slot positions** — every decode step runs ONE program over the
  whole batch with a ``(B,)`` position vector (``attn_decode``'s per-row
  path), so co-batched requests at different depths neither pad nor
  re-compile; with ``decode_impl='pallas'`` the ragged depths feed the
  flash-decode kernel's scalar-prefetch lengths directly;
* **eviction** — EOS, ``max_new_tokens`` or cache exhaustion frees the
  slot for the next queued request mid-flight;
* **metrics** — per-request latency and token counts land in the
  process-wide observability registry (``serving.*``).

Greedy decoding throughout: a given (model, prompt) pair always yields
the same continuation, which is what lets generations participate in the
content-addressed cache (see ``serving/inference.py``).
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.models.registry import LM_FAMILIES, ModelBundle
from repro.observability.metrics import get_registry


def make_prefill_step(bundle: ModelBundle) -> Callable:
    def prefill_step(params, batch, cache):
        logits, cache = bundle.prefill_fn(params, batch, cache)
        next_tok = jnp.argmax(logits[:, -1, :bundle.cfg.vocab_size], axis=-1)
        return next_tok.astype(jnp.int32)[:, None], cache

    return prefill_step


def make_decode_step(bundle: ModelBundle) -> Callable:
    def serve_step(params, cache, tokens, pos):
        logits, cache = bundle.decode_fn(params, cache, tokens, pos)
        next_tok = jnp.argmax(logits[:, -1, :bundle.cfg.vocab_size], axis=-1)
        return next_tok.astype(jnp.int32)[:, None], cache

    return serve_step


# ---------------------------------------------------------------------------
# Continuous-batching scheduler (host-side control, one jitted decode step)
# ---------------------------------------------------------------------------

class QueueFullError(RuntimeError):
    """submit() rejected: the admission queue is at ``max_pending``."""


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new_tokens: int
    generated: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    finish_reason: str = ""           # 'eos' | 'length' | 'cache_full'
    submitted_at: float = 0.0
    started_at: float = 0.0
    finished_at: float = 0.0


class BatchScheduler:
    """Slot-based continuous batching with per-slot decode positions.

    ``batch_size`` fixes the decode micro-batch (the compiled program's
    batch dim); requests beyond that wait in the FIFO queue and are
    admitted the moment a slot is evicted. ``max_len`` bounds prompt +
    generation per slot.
    """

    def __init__(self, bundle: ModelBundle, params: Any, batch_size: int,
                 max_len: int, eos_id: int = -1,
                 max_pending: int | None = None):
        if bundle.cfg.family not in LM_FAMILIES:
            raise ValueError(
                f"BatchScheduler drives KV-cache LM families {LM_FAMILIES}, "
                f"not {bundle.cfg.family!r} (recurrent families have no "
                f"per-slot cache rows to splice)")
        self.bundle = bundle
        self.params = params
        self.batch_size = batch_size
        self.max_len = max_len
        self.eos_id = eos_id
        if max_pending is not None and max_pending < 1:
            raise ValueError(f"max_pending must be >= 1, got {max_pending}")
        #: admission bound: submissions beyond batch-occupancy + this many
        #: queued requests are rejected (backpressure to the caller)
        #: instead of growing the FIFO without limit
        self.max_pending = max_pending
        self.queue: deque[Request] = deque()
        self.slots: list[Request | None] = [None] * batch_size
        self.decode_step = jax.jit(make_decode_step(bundle),
                                   donate_argnums=(1,))
        # one-row prefill; retraces per distinct prompt length (serving
        # workloads draw from a small set of lengths — see docs/serving.md)
        self.prefill_step = jax.jit(make_prefill_step(bundle))
        self._insert_row = jax.jit(self._insert_row_impl, donate_argnums=(0,))
        self.cache = bundle.init_cache(batch_size, max_len)
        # host-side control state: last token + cache depth per slot. Empty
        # slots keep a frozen pos — their rows are never read, and admission
        # overwrites the whole row before re-activating one.
        self.tokens = np.zeros((batch_size, 1), np.int32)
        self.pos = np.zeros(batch_size, np.int32)
        reg = get_registry()
        self._m_submitted = reg.counter("serving.requests_submitted")
        self._m_completed = reg.counter("serving.requests_completed")
        self._m_evicted = reg.counter("serving.slot_evictions")
        self._m_decode_steps = reg.counter("serving.decode_steps")
        self._m_prefill_tokens = reg.counter("serving.prefill_tokens")
        self._m_tokens = reg.counter("serving.tokens_generated")
        self._g_active = reg.gauge("serving.slots_active")
        self._g_queue = reg.gauge("serving.queue_depth")
        self._h_latency = reg.histogram("serving.request_seconds")
        self._m_rejected = reg.counter("serving.rejected")

    # -- admission -----------------------------------------------------------
    def submit(self, req: Request) -> None:
        if len(req.prompt) >= self.max_len:
            raise ValueError(f"prompt of {len(req.prompt)} tokens cannot fit "
                             f"a max_len={self.max_len} cache")
        if (self.max_pending is not None
                and len(self.queue) >= self.max_pending):
            self._m_rejected.inc()
            raise QueueFullError(
                f"admission queue full: {len(self.queue)} pending "
                f"(max_pending={self.max_pending}); retry after the batch "
                "drains or raise max_pending")
        req.submitted_at = time.monotonic()
        self.queue.append(req)
        self._m_submitted.inc()
        self._g_queue.set(len(self.queue))

    @staticmethod
    def _insert_row_impl(full_cache, row_cache, slot):
        return jax.tree.map(
            lambda f, r: lax.dynamic_update_slice_in_dim(
                f, r.astype(f.dtype), slot, axis=1),
            full_cache, row_cache)

    def _prefill_into_slot(self, req: Request, slot: int) -> None:
        req.started_at = time.monotonic()
        prompt = jnp.asarray(req.prompt, jnp.int32)[None, :]
        row_cache = self.bundle.init_cache(1, self.max_len)
        first_tok, row_cache = self.prefill_step(
            self.params, {"tokens": prompt}, row_cache)
        self.cache = self._insert_row(self.cache, row_cache,
                                      jnp.asarray(slot, jnp.int32))
        self.slots[slot] = req
        self.pos[slot] = len(req.prompt)
        tok = int(jax.device_get(first_tok)[0, 0])
        self.tokens[slot, 0] = tok
        req.generated = [tok]
        self._m_prefill_tokens.inc(len(req.prompt))
        self._m_tokens.inc()

    def _admit(self) -> list[Request]:
        """Fill free slots from the queue; returns requests that finished
        at admission (single-token generations)."""
        finished = []
        for i in range(self.batch_size):
            if self.slots[i] is not None or not self.queue:
                continue
            req = self.queue.popleft()
            self._prefill_into_slot(req, i)
            if self._maybe_finish(i):
                finished.append(req)
        self._g_queue.set(len(self.queue))
        self._g_active.set(sum(s is not None for s in self.slots))
        return finished

    # -- eviction ------------------------------------------------------------
    def _maybe_finish(self, slot: int) -> bool:
        req = self.slots[slot]
        if req.generated and req.generated[-1] == self.eos_id:
            req.finish_reason = "eos"
        elif len(req.generated) >= req.max_new_tokens:
            req.finish_reason = "length"
        elif int(self.pos[slot]) >= self.max_len - 1:
            req.finish_reason = "cache_full"
        else:
            return False
        req.done = True
        req.finished_at = time.monotonic()
        self.slots[slot] = None
        self._m_completed.inc()
        self._m_evicted.inc()
        self._h_latency.observe(req.finished_at - req.submitted_at)
        return True

    # -- the decode loop -----------------------------------------------------
    def step(self) -> list[Request]:
        """Admit waiting requests, then run ONE decode step across all
        active slots; returns the requests that finished this step."""
        finished = self._admit()
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            self._g_active.set(0)
            return finished
        next_tok, self.cache = self.decode_step(
            self.params, self.cache, jnp.asarray(self.tokens),
            jnp.asarray(self.pos, jnp.int32))
        self._m_decode_steps.inc()
        next_host = jax.device_get(next_tok)[:, 0]
        for i in active:
            req = self.slots[i]
            req.generated.append(int(next_host[i]))
            self.pos[i] += 1
            self.tokens[i, 0] = int(next_host[i])
            self._m_tokens.inc()
            if self._maybe_finish(i):
                finished.append(req)
        self._g_active.set(sum(s is not None for s in self.slots))
        return finished

    def run(self) -> list[Request]:
        """Drain queue + slots to completion; finished in completion order."""
        finished: list[Request] = []
        while self.queue or any(s is not None for s in self.slots):
            finished.extend(self.step())
        return finished
