"""Serving steps: prefill and single-token decode (greedy), plus a simple
continuous-batching request scheduler used by examples/serve_lm.py.

``make_decode_step`` is what the dry-run lowers for ``decode_*`` and
``long_*`` cells (one new token against a seq_len-deep KV cache)."""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.registry import ModelBundle


def make_prefill_step(bundle: ModelBundle) -> Callable:
    def prefill_step(params, batch, cache):
        logits, cache = bundle.prefill_fn(params, batch, cache)
        next_tok = jnp.argmax(logits[:, -1, :bundle.cfg.vocab_size], axis=-1)
        return next_tok.astype(jnp.int32)[:, None], cache

    return prefill_step


def make_decode_step(bundle: ModelBundle) -> Callable:
    def serve_step(params, cache, tokens, pos):
        logits, cache = bundle.decode_fn(params, cache, tokens, pos)
        next_tok = jnp.argmax(logits[:, -1, :bundle.cfg.vocab_size], axis=-1)
        return next_tok.astype(jnp.int32)[:, None], cache

    return serve_step


# ---------------------------------------------------------------------------
# Minimal continuous-batching scheduler (host-side)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new_tokens: int
    generated: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class BatchScheduler:
    """Greedy slot-based continuous batching over a fixed decode batch."""

    def __init__(self, bundle: ModelBundle, params: Any, batch_size: int,
                 max_len: int, eos_id: int = -1):
        self.bundle = bundle
        self.params = params
        self.batch_size = batch_size
        self.max_len = max_len
        self.eos_id = eos_id
        self.queue: deque[Request] = deque()
        self.slots: list[Request | None] = [None] * batch_size
        self.decode_step = jax.jit(make_decode_step(bundle), donate_argnums=(1,))
        self.cache = bundle.init_cache(batch_size, max_len)
        self.tokens = jnp.zeros((batch_size, 1), jnp.int32)
        self.pos = 0

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _fill_slots(self) -> None:
        for i, slot in enumerate(self.slots):
            if (slot is None or slot.done) and self.queue:
                req = self.queue.popleft()
                self.slots[i] = req
                # naive: feed prompt tokens one at a time via decode steps
                toks = self.tokens.at[i, 0].set(req.prompt[0])
                self.tokens = toks
                req.generated = []

    def step(self) -> list[Request]:
        """One decode step across all active slots; returns finished reqs."""
        self._fill_slots()
        if all(s is None for s in self.slots):
            return []
        next_tok, self.cache = self.decode_step(
            self.params, self.cache, self.tokens, jnp.asarray(self.pos))
        self.pos += 1
        next_host = jax.device_get(next_tok)[:, 0].tolist()
        finished = []
        for i, req in enumerate(self.slots):
            if req is None or req.done:
                continue
            consumed = 1 + self.pos  # prompt feeding progress (approximate)
            if len(req.generated) < len(req.prompt) - 1:
                # still feeding the prompt teacher-forced
                req.generated.append(req.prompt[min(len(req.generated) + 1,
                                                    len(req.prompt) - 1)])
            else:
                req.generated.append(int(next_host[i]))
            del consumed
            self.tokens = self.tokens.at[i, 0].set(req.generated[-1])
            if (len(req.generated) >= len(req.prompt) - 1 + req.max_new_tokens
                    or req.generated[-1] == self.eos_id):
                req.done = True
                finished.append(req)
        return finished
