"""Provenance-tracked LM generation as a first-class engine workload.

:func:`generate` is a calcfunction: every generation is a process node
whose inputs (architecture, parameter seed, prompt tokens, decode
settings) are content-fingerprinted by the caching layer exactly like
any other calculation. Greedy decoding makes the mapping
``(arch, seed, prompt, settings) -> continuation`` a pure function, so

* with caching enabled, an identical prompt is served from the
  provenance graph with **zero decode steps** — the cache-hit fast path
  clones the stored ``tokens``/``stats`` outputs without touching jax;
* generations travel in archives and serve hits across profiles, like
  every other finished-ok calculation.

The execution side is a per-OS-process :class:`ServingEngine` memo: one
compiled :class:`~repro.serving.serve.BatchScheduler` per (arch, seed,
cache size) that cold prompts are batched through. The reduced demo
config decodes through the Pallas flash-decode kernel
(``decode_impl='pallas'``, interpreted off-TPU) so the serving hot loop
exercises the same kernel the TPU path runs.
"""

from __future__ import annotations

import hashlib
import time
from typing import Any

import numpy as np

from repro.core.datatypes import ArrayData, Dict, Int, Str
from repro.core.process_functions import calcfunction
from repro.serving.serve import BatchScheduler, Request

#: serving defaults for the reduced demo model (kept deliberately small —
#: CPU interpret-mode decode must stay test-friendly)
DEFAULT_ARCH = "aiida-demo-110m"
DEFAULT_BATCH_SIZE = 4
_MIN_CACHE = 128

_ENGINES: dict[tuple, "ServingEngine"] = {}


def _serving_config(arch: str, decode_impl: str):
    from repro.configs import reduced_config

    cfg = reduced_config(arch)
    return cfg.replace(decode_impl=decode_impl)


class ServingEngine:
    """One compiled scheduler + params, reused across generate() calls."""

    def __init__(self, arch: str, seed: int, max_len: int,
                 batch_size: int = DEFAULT_BATCH_SIZE,
                 eos_id: int = -1, decode_impl: str = "pallas"):
        import jax

        from repro.models.registry import build

        self.arch, self.seed = arch, int(seed)
        self.cfg = _serving_config(arch, decode_impl)
        self.bundle = build(self.cfg)
        self.params = self.bundle.init_params(jax.random.PRNGKey(int(seed)))
        self.scheduler = BatchScheduler(self.bundle, self.params,
                                        batch_size=batch_size,
                                        max_len=max_len, eos_id=eos_id)
        self._next_rid = 0

    def generate_many(self, prompts: list[list[int]],
                      max_new_tokens: int) -> list[Request]:
        """Continuous-batch a whole prompt list; results in request order."""
        reqs = []
        for p in prompts:
            req = Request(rid=self._next_rid, prompt=list(map(int, p)),
                          max_new_tokens=int(max_new_tokens))
            self._next_rid += 1
            self.scheduler.submit(req)
            reqs.append(req)
        self.scheduler.run()
        return reqs

    def generate_one(self, prompt: list[int], max_new_tokens: int) -> Request:
        return self.generate_many([prompt], max_new_tokens)[0]


def get_engine(arch: str = DEFAULT_ARCH, seed: int = 0, *,
               need_len: int = _MIN_CACHE, batch_size: int = DEFAULT_BATCH_SIZE,
               eos_id: int = -1, decode_impl: str = "pallas") -> ServingEngine:
    """Memoised engine; ``need_len`` is bucketed to a power of two so one
    compiled cache serves a band of request sizes."""
    max_len = _MIN_CACHE
    while max_len < int(need_len) + 1:
        max_len *= 2
    key = (arch, int(seed), max_len, batch_size, eos_id, decode_impl)
    eng = _ENGINES.get(key)
    if eng is None:
        eng = _ENGINES[key] = ServingEngine(
            arch, seed, max_len, batch_size=batch_size, eos_id=eos_id,
            decode_impl=decode_impl)
    return eng


def reset_engines() -> None:
    """Drop the compiled-engine memo (test isolation)."""
    _ENGINES.clear()


def prompt_fingerprint(arch: str, seed: int, prompt: Any) -> str:
    """The serving-side prompt-prefix fingerprint: sha256 over the model
    identity and the exact prompt token sequence. Two requests with the
    same fingerprint are guaranteed the same continuation (greedy), which
    is the property the content-addressed cache exploits."""
    toks = np.asarray(prompt, np.int32)
    h = hashlib.sha256()
    h.update(f"{arch}|{int(seed)}|".encode())
    h.update(toks.tobytes())
    return h.hexdigest()


@calcfunction
def generate(arch: Str, prompt: ArrayData, max_new_tokens: Int,
             seed: Int, eos_id: Int):
    """Greedy continuation of ``prompt`` under the (reduced) ``arch`` model
    with parameters drawn from ``seed``. Returns the generated tokens plus
    a stats document; both are provenance outputs, so identical calls are
    cache hits that never re-decode."""
    toks = [int(t) for t in np.asarray(prompt.value).reshape(-1)]
    new = int(max_new_tokens.value)
    eng = get_engine(str(arch.value), int(seed.value),
                     need_len=len(toks) + new, eos_id=int(eos_id.value))
    t0 = time.monotonic()
    req = eng.generate_many([toks], new)[0]
    dt = time.monotonic() - t0
    return {
        "tokens": ArrayData(np.asarray(req.generated, np.int32)),
        "stats": Dict({
            "prompt_tokens": len(toks),
            "new_tokens": len(req.generated),
            "finish_reason": req.finish_reason,
            "fingerprint": prompt_fingerprint(str(arch.value),
                                              int(seed.value), toks),
            "wall_seconds": dt,
        }),
    }
