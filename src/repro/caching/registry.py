"""Cache lookups over the provenance graph.

The provenance store itself is the cache: every process node records its
input fingerprint in the indexed ``node_hash`` column, so a lookup is one
SELECT over ``(process_type, node_hash)``. Only *finished-ok* nodes serve
as sources; invalidation simply clears ``node_hash`` on the source nodes
(their provenance is untouched — they just stop matching).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

from repro.provenance.store import LinkType, ProvenanceStore, QueryBuilder

_OUTPUT_LINKS = (LinkType.CREATE.value, LinkType.RETURN.value)


@dataclass
class CacheHit:
    pk: int
    uuid: str
    process_type: str
    exit_status: int
    exit_message: str | None
    # (label, link_type value, data node pk) for each CREATE/RETURN edge
    outputs: list[tuple[str, str, int]] = field(default_factory=list)


class CacheRegistry:
    def __init__(self, store: ProvenanceStore):
        self.store = store

    def find_cached(self, process_type: str, input_hash: str,
                    exclude_pk: int | None = None) -> CacheHit | None:
        """Most recent finished-ok node with this fingerprint, plus its
        output edges — or None."""
        if not input_hash:
            return None
        rows = (QueryBuilder(self.store)
                .with_process_type(process_type)
                .with_hash(input_hash)
                .with_state("finished")
                .with_exit_status(0)
                .order_by("pk", desc=True)
                .limit(2)   # newest match + one spare in case it's self
                .all())
        for row in rows:
            if exclude_pk is not None and row["pk"] == exclude_pk:
                continue
            outputs = [(label, lt, pk)
                       for pk, lt, label in self.store.outgoing(row["pk"])
                       if lt in _OUTPUT_LINKS]
            return CacheHit(pk=row["pk"], uuid=row["uuid"],
                            process_type=process_type,
                            exit_status=row["exit_status"],
                            exit_message=row["exit_message"],
                            outputs=outputs)
        return None

    # -- observability ------------------------------------------------------
    def stats(self) -> dict[str, Any]:
        """Per-process-type hashed-node counts, distinct fingerprints and
        cache-hit (cloned) node counts."""
        conn = self.store._conn()
        rows = conn.execute(
            "SELECT process_type, COUNT(*) AS n,"
            " COUNT(DISTINCT node_hash) AS distinct_hashes,"
            " SUM(CASE WHEN json_extract(attributes, '$.cached_from')"
            "     IS NOT NULL THEN 1 ELSE 0 END) AS hits"
            " FROM nodes WHERE node_hash IS NOT NULL"
            " AND node_type LIKE 'process%'"
            " GROUP BY process_type ORDER BY process_type").fetchall()
        per_type = {r["process_type"]: {
            "hashed_nodes": r["n"],
            "distinct_hashes": r["distinct_hashes"],
            "cache_hits": r["hits"] or 0,
        } for r in rows}
        return {
            "process_types": per_type,
            "hashed_nodes": sum(v["hashed_nodes"] for v in per_type.values()),
            "cache_hits": sum(v["cache_hits"] for v in per_type.values()),
        }

    def equivalents(self, pk: int) -> list[int]:
        """Other process nodes sharing this node's fingerprint."""
        node = self.store.get_node(pk)
        if not node or not node.get("node_hash"):
            return []
        rows = (QueryBuilder(self.store)
                .with_hash(node["node_hash"]).all())
        return [r["pk"] for r in rows if r["pk"] != pk]

    # -- invalidation --------------------------------------------------------
    def invalidate(self, *, pk: int | None = None,
                   process_type: str | None = None) -> int:
        """Clear fingerprints so nodes stop serving as cache sources.
        Give a pk, a process_type, or neither (= everything). Returns the
        number of nodes invalidated."""
        conn = self.store._conn()
        with self.store._lock:
            if pk is not None:
                cur = conn.execute(
                    "UPDATE nodes SET node_hash=NULL WHERE pk=?"
                    " AND node_hash IS NOT NULL", (pk,))
            elif process_type is not None:
                cur = conn.execute(
                    "UPDATE nodes SET node_hash=NULL WHERE process_type=?"
                    " AND node_hash IS NOT NULL", (process_type,))
            else:
                cur = conn.execute(
                    "UPDATE nodes SET node_hash=NULL"
                    " WHERE node_hash IS NOT NULL")
            conn.commit()
        return cur.rowcount
