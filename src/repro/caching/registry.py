"""Cache lookups over the provenance graph.

The provenance store itself is the cache: every process node records its
input fingerprint in the indexed ``node_hash`` column, so a lookup is one
SELECT over ``(process_type, node_hash)``. Only *finished-ok* nodes serve
as sources; invalidation simply clears ``node_hash`` on the source nodes
(their provenance is untouched — they just stop matching).
"""

from __future__ import annotations

import hashlib
import json
import sqlite3
from dataclasses import dataclass, field
from typing import Any

from repro.provenance.store import LinkType, ProvenanceStore, QueryBuilder

_OUTPUT_LINKS = (LinkType.CREATE.value, LinkType.RETURN.value)


@dataclass
class CacheHit:
    pk: int
    uuid: str
    process_type: str
    exit_status: int
    exit_message: str | None
    # (label, link_type value, data node pk) for each CREATE/RETURN edge
    outputs: list[tuple[str, str, int]] = field(default_factory=list)


class CacheRegistry:
    def __init__(self, store: ProvenanceStore):
        self.store = store

    #: key prefix for the per-process-type hash-collision counters
    COLLISION_KEY = "cache_collisions"
    #: how many equivalent sources to cross-check per cache hit (bounds
    #: the extra payload loads on the hot path)
    _COLLISION_PROBE = 2

    def find_cached(self, process_type: str, input_hash: str,
                    exclude_pk: int | None = None) -> CacheHit | None:
        """Most recent finished-ok node with this fingerprint, plus its
        output edges — or None. When several finished-ok sources share the
        fingerprint, their outputs are cross-checked by content: a
        same-hash/different-outputs pair is a *hash collision* (the
        fingerprint failed to capture something that changed the result)
        and increments the durable ``cache_collisions.<type>`` counter
        surfaced by ``repro cache stats``."""
        if not input_hash:
            return None
        rows = (QueryBuilder(self.store)
                .with_process_type(process_type)
                .with_hash(input_hash)
                .with_state("finished")
                .with_exit_status(0)
                .order_by("pk", desc=True)
                .limit(2 + self._COLLISION_PROBE)
                .project("pk", "uuid", "exit_status", "exit_message")
                .all())
        viable = [row for row in rows
                  if exclude_pk is None or row["pk"] != exclude_pk]
        if not viable:
            return None
        row = viable[0]
        outputs = [(label, lt, pk)
                   for pk, lt, label in self.store.outgoing(row["pk"])
                   if lt in _OUTPUT_LINKS]
        if len(viable) > 1:
            self._record_collisions(process_type, row["pk"], outputs,
                                    viable[1:])
        return CacheHit(pk=row["pk"], uuid=row["uuid"],
                        process_type=process_type,
                        exit_status=row["exit_status"],
                        exit_message=row["exit_message"],
                        outputs=outputs)

    # -- hash-collision telemetry -------------------------------------------
    def _output_digest(self, outputs: list[tuple[str, str, int]]) -> str:
        """Content digest of a node's output set: sorted (label, link
        type, payload hash) triples — node identity does not matter."""
        from repro.caching.hashing import hash_data_value

        triples = sorted(
            (label, lt, hash_data_value(self.store.load_data(pk)))
            for label, lt, pk in outputs)
        return hashlib.sha256(
            json.dumps(triples, sort_keys=True).encode()).hexdigest()

    def _output_digest_for(self, pk: int,
                           outputs: list[tuple[str, str, int]] | None = None
                           ) -> str:
        """The node's output digest, memoized in its attributes — the
        probe on the cache-hit hot path must not re-load full payloads
        (arrays, folders) on every lookup. Clones inherit the digest from
        their source via the attribute carry-over, which is sound because
        their outputs are content-identical by construction."""
        attrs = json.loads(
            (self.store.get_node(pk, columns=("attributes",)) or {})
            .get("attributes") or "{}")
        cached = attrs.get("output_digest")
        if cached:
            return cached
        if outputs is None:
            outputs = [(label, lt, out_pk)
                       for out_pk, lt, label in self.store.outgoing(pk)
                       if lt in _OUTPUT_LINKS]
        digest = self._output_digest(outputs)
        self.store.update_process(pk, attributes={"output_digest": digest})
        return digest

    def _record_collisions(self, process_type: str, hit_pk: int,
                           hit_outputs: list[tuple[str, str, int]],
                           others: list[dict]) -> None:
        """Count same-``node_hash``/different-outputs occurrences on the
        cache-hit path (bounded probe; telemetry must never break a run)."""
        try:
            reference = self._output_digest_for(hit_pk, hit_outputs)
            for row in others[:self._COLLISION_PROBE]:
                if self._output_digest_for(row["pk"]) != reference:
                    self.store.incr_meta(
                        f"{self.COLLISION_KEY}.{process_type}")
                    break   # one occurrence per lookup, not per pair
        except Exception:  # noqa: BLE001 — telemetry only
            pass

    def collision_counts(self) -> dict[str, int]:
        """Per-process-type hash-collision occurrence counters."""
        prefix = f"{self.COLLISION_KEY}."
        return {key[len(prefix):]: int(value) for key, value
                in self.store.all_meta(prefix).items()}

    # -- observability ------------------------------------------------------
    def stats(self) -> dict[str, Any]:
        """Per-process-type hashed-node counts, distinct fingerprints and
        cache-hit (cloned) node counts."""
        conn = self.store._conn()
        rows = conn.execute(
            "SELECT process_type, COUNT(*) AS n,"
            " COUNT(DISTINCT node_hash) AS distinct_hashes,"
            " SUM(CASE WHEN json_extract(attributes, '$.cached_from')"
            "     IS NOT NULL THEN 1 ELSE 0 END) AS hits"
            " FROM nodes WHERE node_hash IS NOT NULL"
            " AND node_type LIKE 'process%'"
            " GROUP BY process_type ORDER BY process_type").fetchall()
        collisions = self.collision_counts()
        per_type = {r["process_type"]: {
            "hashed_nodes": r["n"],
            "distinct_hashes": r["distinct_hashes"],
            "cache_hits": r["hits"] or 0,
            "hash_collisions": collisions.get(r["process_type"], 0),
        } for r in rows}
        return {
            "process_types": per_type,
            "hashed_nodes": sum(v["hashed_nodes"] for v in per_type.values()),
            "cache_hits": sum(v["cache_hits"] for v in per_type.values()),
            "hash_collisions": sum(collisions.values()),
        }

    def equivalents(self, pk: int) -> list[int]:
        """Other process nodes sharing this node's fingerprint."""
        node = self.store.get_node(pk)
        if not node or not node.get("node_hash"):
            return []
        rows = (QueryBuilder(self.store)
                .with_hash(node["node_hash"]).project("pk").all())
        return [r["pk"] for r in rows if r["pk"] != pk]

    # -- invalidation --------------------------------------------------------
    def invalidate(self, *, pk: int | None = None,
                   process_type: str | None = None) -> int:
        """Clear fingerprints so nodes stop serving as cache sources.
        Give a pk, a process_type, or neither (= everything). Returns the
        number of nodes invalidated."""
        conn = self.store._conn()
        # also stamp `cache_invalidated` so `repro cache backfill` knows
        # the cleared fingerprint was deliberate and must not be restored
        mark = ("attributes=json_patch(COALESCE(attributes,'{}'),"
                " '{\"cache_invalidated\": true}')")
        with self.store._lock:
            try:
                if pk is not None:
                    cur = conn.execute(
                        f"UPDATE nodes SET node_hash=NULL, {mark} WHERE pk=?"
                        " AND node_hash IS NOT NULL", (pk,))
                elif process_type is not None:
                    cur = conn.execute(
                        f"UPDATE nodes SET node_hash=NULL, {mark}"
                        " WHERE process_type=?"
                        " AND node_hash IS NOT NULL", (process_type,))
                else:
                    cur = conn.execute(
                        f"UPDATE nodes SET node_hash=NULL, {mark}"
                        " WHERE node_hash IS NOT NULL")
            except sqlite3.OperationalError:
                # sqlite built without JSON1: clear the hashes unmarked
                # (backfill may then re-fingerprint these nodes)
                if pk is not None:
                    cur = conn.execute(
                        "UPDATE nodes SET node_hash=NULL WHERE pk=?"
                        " AND node_hash IS NOT NULL", (pk,))
                elif process_type is not None:
                    cur = conn.execute(
                        "UPDATE nodes SET node_hash=NULL WHERE process_type=?"
                        " AND node_hash IS NOT NULL", (process_type,))
                else:
                    cur = conn.execute(
                        "UPDATE nodes SET node_hash=NULL"
                        " WHERE node_hash IS NOT NULL")
            conn.commit()
        return cur.rowcount
