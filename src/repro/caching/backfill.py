"""Re-hash legacy (pre-caching) process nodes so they serve cache hits.

Databases created before the caching subsystem have ``node_hash = NULL``
on every process node, so none of that already-computed work can ever be
reused. The backfill walks cacheable process nodes that lack a
fingerprint, reconstructs each node's input mapping from its stored
``INPUT_*`` links, recomputes :func:`~repro.caching.hashing.compute_input_hash`
with the *real* process class (so backfilled hashes are bit-identical to
the ones a fresh launch computes) and writes the result back — in
batches, idempotently, with ``--dry-run`` support and durable progress /
collision telemetry via ``ProvenanceStore.incr_meta``.

Nodes whose fingerprint was *deliberately* cleared with
``repro cache invalidate`` carry a ``cache_invalidated`` attribute and
are skipped (pass ``include_invalidated=True`` to re-hash them anyway).
"""

from __future__ import annotations

import importlib
import json
from dataclasses import dataclass, field
from typing import Callable, Iterable, Mapping

from repro.caching.hashing import compute_input_hash
from repro.provenance.store import (
    LinkType, NodeType, ProvenanceStore, QueryBuilder,
)

#: node types whose processes are hashable at all (mirrors
#: repro.caching.config._is_cacheable's default)
_CACHEABLE_NODE_TYPES = (NodeType.CALC_FUNCTION, NodeType.CALC_JOB)

#: modules searched for process classes when no explicit registry is given
_DEFAULT_MODULES = ("repro.calcjobs", "repro.core")

_INPUT_LINKS = (LinkType.INPUT_CALC.value, LinkType.INPUT_WORK.value)

#: meta keys for durable backfill telemetry (shown by `repro cache stats`
#: consumers via ProvenanceStore.all_meta)
META_HASHED = "cache_backfill.hashed"
META_RUNS = "cache_backfill.runs"


@dataclass
class BackfillStats:
    scanned: int = 0
    hashed: int = 0
    skipped_unresolvable: int = 0
    skipped_invalidated: int = 0
    skipped_error: int = 0
    collisions: int = 0
    dry_run: bool = False
    #: process_type -> count of nodes hashed
    by_type: dict[str, int] = field(default_factory=dict)

    @property
    def skipped(self) -> int:
        return (self.skipped_unresolvable + self.skipped_invalidated +
                self.skipped_error)


class ClassResolver:
    """Map a stored ``process_type`` name back to its Process class.

    Resolution order: an explicit ``classes`` mapping, then attribute
    lookup in ``modules`` (process-function wrappers are unwrapped via
    their ``process_class``). The stored name is the class ``__name__``,
    so callers with processes defined outside the default modules pass
    their module paths (CLI: ``--resolve mypkg.flows``).
    """

    def __init__(self, classes: Mapping[str, type] | None = None,
                 modules: Iterable[str] = ()):
        from repro.core.process import Process

        self._process_base = Process
        self._registry: dict[str, type | None] = dict(classes or {})
        self._modules = []
        for name in (*modules, *_DEFAULT_MODULES):
            try:
                self._modules.append(importlib.import_module(name))
            except ImportError:
                pass

    def resolve(self, process_type: str) -> type | None:
        if process_type in self._registry:
            return self._registry[process_type]
        found: type | None = None
        for mod in self._modules:
            obj = getattr(mod, process_type, None)
            if obj is None:
                continue
            if isinstance(obj, type) and issubclass(obj, self._process_base):
                found = obj
                break
            proc_cls = getattr(obj, "process_class", None)
            if isinstance(proc_cls, type) and \
                    issubclass(proc_cls, self._process_base):
                found = proc_cls
                break
        self._registry[process_type] = found   # cache misses too
        return found


def _inputs_from_links(store: ProvenanceStore, pk: int, ns) -> dict:
    """Rebuild the (db-stored part of the) input mapping of a process
    node from its incoming INPUT_* links, un-flattening ``a__b`` labels
    against the class's port tree the same way the cache-clone path does:
    a ``__`` segment descends only when the prefix names a declared
    PortNamespace (or lands in a dynamic namespace); a flat label that
    merely contains ``__`` stays flat."""
    from repro.core.ports import PortNamespace

    tree: dict = {}
    for src_pk, lt, label in store.incoming(pk):
        if lt not in _INPUT_LINKS:
            continue
        value = store.load_data(src_pk)
        parts = label.split("__")
        cur_ns, cur = ns, tree
        while len(parts) > 1:
            head = parts[0]
            port = cur_ns.get(head) if cur_ns is not None else None
            if isinstance(port, PortNamespace):
                cur = cur.setdefault(head, {})
                cur_ns = port
                parts = parts[1:]
                continue
            if port is None and cur_ns is not None and \
                    getattr(cur_ns, "dynamic", False) and len(parts) == 2:
                # dynamic-namespace mapping values link as <key>__<sub>
                cur = cur.setdefault(head, {})
                cur_ns = None
                parts = parts[1:]
                continue
            break  # flat label that happens to contain '__'
        cur["__".join(parts)] = value
    return tree


def backfill_hashes(store: ProvenanceStore, *,
                    classes: Mapping[str, type] | None = None,
                    resolve_modules: Iterable[str] = (),
                    process_types: Iterable[str] | None = None,
                    batch_size: int = 200,
                    dry_run: bool = False,
                    include_invalidated: bool = False,
                    collision_check: bool = True,
                    progress: Callable[[str], None] | None = None
                    ) -> BackfillStats:
    """Fingerprint every cacheable process node with ``node_hash = NULL``.

    Idempotent: re-running scans only nodes still lacking a hash, so a
    completed backfill is a no-op. ``dry_run`` computes and reports
    without writing anything — no hashes, no telemetry, and the
    collision probe is skipped too (its registry lookups memoize output
    digests into node attributes, which a dry run must not do).
    """
    stats = BackfillStats(dry_run=dry_run)
    say = progress or (lambda _msg: None)
    resolver = ClassResolver(classes, resolve_modules)
    wanted = set(process_types) if process_types else None
    registry = None
    if collision_check and not dry_run:
        from repro.caching.registry import CacheRegistry

        registry = CacheRegistry(store)

    qb = (QueryBuilder(store)
          .with_node_types(_CACHEABLE_NODE_TYPES)
          .with_null_hash()
          .order_by("pk"))
    candidates = [row for row in qb.all()
                  if wanted is None or row["process_type"] in wanted]

    for start in range(0, len(candidates), batch_size):
        batch = candidates[start:start + batch_size]
        for row in batch:
            stats.scanned += 1
            attrs = json.loads(row.get("attributes") or "{}")
            if attrs.get("cache_invalidated") and not include_invalidated:
                stats.skipped_invalidated += 1
                continue
            cls = resolver.resolve(row["process_type"] or "")
            if cls is None:
                stats.skipped_unresolvable += 1
                continue
            try:
                ns = cls.spec().inputs
                inputs = _inputs_from_links(store, row["pk"], ns)
                node_hash = compute_input_hash(cls, inputs, ns=ns)
            except Exception:  # noqa: BLE001 — one bad node must not
                stats.skipped_error += 1       # abort the whole backfill
                continue
            if registry is not None and \
                    row.get("process_state") == "finished" and \
                    row.get("exit_status") == 0:
                # would this node join an equivalence class whose outputs
                # disagree with its own? count it like the hit-path does
                hit = registry.find_cached(row["process_type"], node_hash,
                                           exclude_pk=row["pk"])
                if hit is not None:
                    try:
                        mine = registry._output_digest_for(row["pk"])
                        theirs = registry._output_digest_for(hit.pk,
                                                             hit.outputs)
                        if mine != theirs:
                            stats.collisions += 1
                            store.incr_meta(
                                f"{registry.COLLISION_KEY}."
                                f"{row['process_type']}")
                    except Exception:  # noqa: BLE001 — telemetry only
                        pass
            if not dry_run:
                store.set_node_hash(row["pk"], node_hash)
            stats.hashed += 1
            stats.by_type[row["process_type"]] = \
                stats.by_type.get(row["process_type"], 0) + 1
        done = min(start + batch_size, len(candidates))
        say(f"  batch {start // batch_size + 1}: "
            f"{done}/{len(candidates)} scanned, {stats.hashed} hashed"
            + (" (dry run)" if dry_run else ""))

    if not dry_run and stats.hashed:
        store.incr_meta(META_HASHED, stats.hashed)
    if not dry_run:
        store.incr_meta(META_RUNS)
    return stats
