"""Caching policy: which process types may take the cache-hit fast path.

Three layers, strongest first:

1. ``enable_caching()`` / ``disable_caching()`` context managers — the
   innermost active frame that mentions a process type (or all types)
   decides.
2. The ``REPRO_CACHING`` environment variable — ``1``/``all``/``true``
   enables every cacheable type, ``0``/``false``/``off`` disables all,
   and any other value is read as a comma-separated list of process-type
   names to enable. This is how daemon workers (separate OS processes,
   which inherit the environment) are switched on.
3. The global :class:`CachingPolicy` defaults (off unless opted in).

Orthogonally, a process class must be *cacheable* at all: calculation-like
processes (calcfunctions, calcjobs) are; workflow-like processes
(workchains, workfunctions) are not, because reusing a workflow node would
silently skip replaying its subprocesses. A class can force either way
with ``CACHEABLE = True/False``.
"""

from __future__ import annotations

import contextlib
import os
from typing import Iterator

ENV_VAR = "REPRO_CACHING"
_TRUE = ("1", "all", "true", "yes", "on")
_FALSE = ("0", "false", "no", "off", "none")


def _is_cacheable(process_cls: type) -> bool:
    explicit = getattr(process_cls, "CACHEABLE", None)
    if explicit is not None:
        return bool(explicit)
    from repro.provenance.store import NodeType

    node_type = getattr(process_cls, "NODE_TYPE", None)
    return node_type in (NodeType.CALC_FUNCTION, NodeType.CALC_JOB)


class CachingPolicy:
    """Per-process-type opt-in/out with a global default."""

    def __init__(self, default_enabled: bool = False):
        self.default_enabled = default_enabled
        self._enabled: set[str] = set()
        self._disabled: set[str] = set()
        # context-manager frames: (enable?, frozenset of names or None=all)
        self._stack: list[tuple[bool, frozenset[str] | None]] = []

    # -- persistent configuration ------------------------------------------
    def enable(self, *process_types: str) -> None:
        if not process_types:
            self.default_enabled = True
            return
        for t in process_types:
            self._enabled.add(t)
            self._disabled.discard(t)

    def disable(self, *process_types: str) -> None:
        if not process_types:
            self.default_enabled = False
            self._enabled.clear()
            return
        for t in process_types:
            self._disabled.add(t)
            self._enabled.discard(t)

    # -- resolution ---------------------------------------------------------
    def is_enabled_for(self, process_cls: type) -> bool:
        if not _is_cacheable(process_cls):
            return False
        name = process_cls.__name__
        for on, names in reversed(self._stack):
            if names is None or name in names:
                return on
        env = os.environ.get(ENV_VAR)
        if env is not None:
            low = env.strip().lower()
            if low in _TRUE:
                return True
            if low in _FALSE or not low:
                return False
            return name in {t.strip() for t in env.split(",")}
        if name in self._disabled:
            return False
        if name in self._enabled:
            return True
        return self.default_enabled


_POLICY = CachingPolicy()


def get_policy() -> CachingPolicy:
    return _POLICY


def reset_policy() -> CachingPolicy:
    """Fresh policy (test isolation)."""
    global _POLICY
    _POLICY = CachingPolicy()
    return _POLICY


def is_caching_enabled_for(process_cls: type) -> bool:
    return _POLICY.is_enabled_for(process_cls)


def _names(process_types: tuple) -> frozenset[str] | None:
    if not process_types:
        return None
    return frozenset(t if isinstance(t, str) else t.__name__
                     for t in process_types)


@contextlib.contextmanager
def enable_caching(*process_types) -> Iterator[None]:
    """Scope in which caching is on — for all cacheable types, or only
    the given ones (names or classes)."""
    frame = (True, _names(process_types))
    _POLICY._stack.append(frame)
    try:
        yield
    finally:
        _POLICY._stack.remove(frame)


@contextlib.contextmanager
def disable_caching(*process_types) -> Iterator[None]:
    """Scope in which caching is off, overriding any outer enablement."""
    frame = (False, _names(process_types))
    _POLICY._stack.append(frame)
    try:
        yield
    finally:
        _POLICY._stack.remove(frame)
