"""Deterministic content hashes for process inputs.

The fingerprint of a process is a sha256 over a canonical JSON document
combining:

* the process type name,
* a per-class version salt (``Process.CACHE_VERSION``; process functions
  additionally salt with a digest of their source code, so editing the
  function body invalidates its old cache entries),
* the db-storable inputs, each reduced to a content digest.

``DataValue`` payloads hash by content, not identity: arrays digest their
dtype + shape + raw bytes (so two equal arrays stored separately collide,
as they should), folders digest their sorted (name, bytes) pairs, and
scalar types digest their canonical JSON payload. ``non_db`` ports and the
``metadata`` namespace are excluded — they describe *how* to run, not
*what* is computed — as are ports declared with ``exclude_from_hash=True``
(tolerances/thresholds that are stored in provenance but do not affect the
result).
"""

from __future__ import annotations

import hashlib
import json
from typing import Any, Mapping

import numpy as _np

from repro.core.datatypes import ArrayData, DataValue, FolderData
from repro.core.ports import PortNamespace
from repro.observability import trace


def _sha256(*chunks: bytes) -> str:
    h = hashlib.sha256()
    for chunk in chunks:
        h.update(chunk)
    return h.hexdigest()


def _canonical_json(obj: Any) -> bytes:
    return json.dumps(obj, sort_keys=True, separators=(",", ":"),
                      default=repr).encode()


def hash_data_value(value: DataValue) -> str:
    """Content digest of a single DataValue (stable across store/reload)."""
    type_tag = f"{value._TYPE}:".encode()
    if isinstance(value, ArrayData):
        arr = _np.ascontiguousarray(value.value)
        header = f"{arr.dtype.str}|{arr.shape}|".encode()
        return _sha256(type_tag, header, arr.tobytes())
    if isinstance(value, FolderData):
        parts = [type_tag]
        for name in value.names():
            data = value.get_bytes(name)
            parts.append(name.encode() + b"\0" +
                         hashlib.sha256(data).digest())
        return _sha256(*parts)
    return _sha256(type_tag, _canonical_json(value.to_payload()))


def _canonicalize(ns: PortNamespace | None, values: Mapping[str, Any],
                  skip_metadata: bool = False) -> dict[str, Any]:
    """Reduce an input mapping to a JSON-safe tree of content digests,
    mirroring the traversal _link_inputs uses for provenance links."""
    out: dict[str, Any] = {}
    for key, value in values.items():
        if skip_metadata and key == "metadata":
            continue  # only the *top-level* metadata namespace is excluded
        port = ns.get(key) if ns is not None else None
        if port is not None and port.non_db:
            continue
        if port is not None and getattr(port, "exclude_from_hash", False):
            # declared as not affecting the result (tolerance/threshold):
            # stored and linked in provenance, but not fingerprinted
            continue
        if isinstance(port, PortNamespace) and isinstance(value, Mapping) \
                and not isinstance(value, DataValue):
            sub = _canonicalize(port, value)
            if sub:
                out[key] = {"__ns__": sub}
            continue
        if isinstance(value, DataValue):
            out[key] = {"__data__": hash_data_value(value)}
        elif isinstance(value, Mapping):
            out[key] = {"__ns__": _canonicalize(None, value)}
        elif isinstance(value, (str, int, float, bool, type(None))):
            out[key] = {"__raw__": value}
        else:
            out[key] = {"__repr__": repr(value)}
    return out


def compute_input_hash(process_cls: type, inputs: Mapping[str, Any],
                       ns: PortNamespace | None = None) -> str:
    """The canonical input fingerprint for one process invocation."""
    with trace.span("cache.hash"):
        if ns is None:
            ns = process_cls.spec().inputs
        document = {
            # fully qualified, so same-named classes in different modules
            # cannot serve each other's outputs
            "process_type": f"{process_cls.__module__}:"
                            f"{process_cls.__qualname__}",
            "salt": str(_cache_salt(process_cls)),
            "inputs": _canonicalize(ns, inputs, skip_metadata=True),
        }
        return _sha256(b"repro-cache-v1:", _canonical_json(document))


def _cache_salt(process_cls: type) -> str:
    salt = getattr(process_cls, "CACHE_VERSION", 1)
    extra = getattr(process_cls, "_cache_extra_salt", "")
    return f"{salt}|{extra}"


def source_salt(fn) -> str:
    """Digest of a function's source, used to salt process-function
    hashes — editing the body invalidates old cache entries."""
    import inspect

    try:
        src = inspect.getsource(fn)
    except (OSError, TypeError):
        return ""
    return hashlib.sha256(src.encode()).hexdigest()[:16]
