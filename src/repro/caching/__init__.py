# Content-addressed process caching (AiiDA 1.0 §caching; paper §II).
#
# A process's inputs are hashed into a canonical fingerprint at node
# creation; before executing, the engine may look up an earlier
# finished-ok node with the same fingerprint and reuse its outputs
# instead of recomputing — provenance stays honest because the outputs
# are cloned as new nodes linked to the new process node, which records
# `cached_from` in its metadata.

from repro.caching.backfill import (  # noqa: F401
    BackfillStats, backfill_hashes,
)
from repro.caching.config import (  # noqa: F401
    CachingPolicy, disable_caching, enable_caching, get_policy,
    is_caching_enabled_for, reset_policy,
)
from repro.caching.hashing import (  # noqa: F401
    compute_input_hash, hash_data_value,
)
from repro.caching.registry import CacheHit, CacheRegistry  # noqa: F401
