"""Checkpoint-heavy workloads for chaos scenarios.

These live in an importable module — NOT in the harness — because daemon
workers recreate processes from their checkpoints by importing
``module:qualname``; classes defined under ``__main__`` cannot cross the
spawn boundary.

``ChaosCalc`` is deliberately a *staged* process: it takes a durable
checkpoint after every stage, so a kill -9 at any moment loses at most
one stage of work and the replacement worker resumes from ``_stage``
rather than from scratch. ``ChaosChain`` adds a call hierarchy on top so
broadcast-dependent parent/child waits are exercised too.
"""

from __future__ import annotations

import asyncio

from repro.core import Float, Int, Process, ToContext, WorkChain, append_
from repro.provenance.store import NodeType


class ChaosCalc(Process):
    """Runs ``steps`` stages, checkpointing after each. Survivable at any
    kill point: the stage counter rides in ``checkpoint_extras``."""

    NODE_TYPE = NodeType.CALC_FUNCTION
    CACHEABLE = False

    _stage = 0  # class default; recreate_from_checkpoint bypasses __init__

    @classmethod
    def define(cls, spec):
        super().define(spec)
        spec.input("steps", valid_type=Int, default=Int(3))
        spec.input("pause", valid_type=Float, default=Float(0.05))
        spec.output("result", valid_type=Int)

    def checkpoint_extras(self) -> dict:
        return {"stage": self._stage}

    def load_checkpoint_extras(self, extras: dict) -> None:
        self._stage = int(extras.get("stage", 0))

    async def run(self):
        steps = self.inputs["steps"].value
        pause = self.inputs["pause"].value
        while self._stage < steps:
            await self.interruptible(asyncio.sleep(pause))
            self._stage += 1
            self.checkpoint_now()
        self.out("result", Int(steps))


class ChaosChain(WorkChain):
    """Fans out ``n`` ChaosCalc children and waits on all of them — the
    parent's WAITING→RUNNING wake-up depends on terminal broadcasts, which
    is exactly what the broker-partition scenario drops."""

    @classmethod
    def define(cls, spec):
        super().define(spec)
        spec.input("n", valid_type=Int, default=Int(2))
        spec.input("steps", valid_type=Int, default=Int(3))
        spec.input("pause", valid_type=Float, default=Float(0.05))
        spec.output("total", valid_type=Int)
        spec.outline(cls.launch, cls.collect)

    def launch(self):
        for _ in range(self.inputs["n"].value):
            self.to_context(children=append_(self.submit(
                ChaosCalc, steps=self.inputs["steps"],
                pause=self.inputs["pause"])))

    def collect(self):
        total = sum(c.outputs["result"].value for c in self.ctx.children)
        self.out("total", Int(total))
