"""Fault-point registry: deterministic, seeded fault injection.

A *fault point* is a named seam in the engine where the paper claims the
system survives a failure (``store.commit.pre``, ``broker.ack.pre``, …).
The instrumented code calls :func:`fault_point` at each seam; when a
:class:`ChaosPlan` is active and one of its rules matches, the plan fires
an *action*:

``crash``      ``os._exit`` — the seam's OS process dies instantly, like
               a kill -9 at exactly that instruction.
``raise``      raise :class:`ChaosInjected` — exercises exception paths
               (e.g. a store transaction rollback mid-commit).
``delay``      sleep for ``delay`` seconds — simulates a stalled fsync or
               a slow network without killing anything.
``duplicate``  *cooperative*: :func:`fault_point` returns the string
               ``"duplicate"`` and the seam re-sends the frame (broker
               task delivery).
``drop``       *cooperative*: returns ``"drop"`` and the seam swallows
               the frame (broker broadcast fan-out — a partition).

Triggers are deterministic under a seed: ``nth`` fires on exactly the
n-th hit of the rule, ``once`` on the first, ``p`` fires per-hit from a
``random.Random(seed)`` stream (optionally capped with ``max``), and no
trigger at all means every hit fires.

Activation: programmatic (``activate(plan)``) or the ``REPRO_CHAOS`` env
spec, which is how the harness arms *spawned daemon workers* — they
inherit the environment across the multiprocessing spawn boundary and
resolve their own plan on first hit:

    REPRO_CHAOS="seed=7;store.commit.pre:crash:nth=5;broker.broadcast.pre:drop:p=0.5,max=40"

Disabled path: one module-global load + ``None`` check (the tracer's
trick), so the seams stay in the hot paths permanently. obs_bench.py
asserts the overhead bar in CI.
"""

from __future__ import annotations

import fnmatch
import os
import random
import sys
import time
from typing import Any

ENV_VAR = "REPRO_CHAOS"

#: default exit code for crash actions — distinctive in worker exitcodes
CRASH_EXIT_CODE = 113

#: every fault point threaded through the codebase. The lint
#: (scripts/check_fault_points.py) asserts this catalog, the
#: ``fault_point("…")`` call sites and docs/chaos.md all agree, so a seam
#: rename cannot silently orphan a scenario.
CATALOG: dict[str, str] = {
    "store.commit.pre": (
        "inside ProvenanceStore just before a transaction (or standalone "
        "write) commits — a crash here loses the whole unit of work"),
    "store.commit.post": (
        "immediately after a store commit returns — durable, but nothing "
        "downstream (broadcast, ack) has happened yet"),
    "process.flush.pre": (
        "engine-step-vs-store-flush seam: the step mutated in-memory "
        "state but _flush_provenance has not written it yet"),
    "process.flush.post": (
        "after a checkpoint flush committed — the narrow window between "
        "durability and the process continuing"),
    "process.terminal.pre": (
        "process body finished, terminal transaction (outputs + final "
        "state + checkpoint removal) not yet started"),
    "daemon.checkpoint.pre": (
        "daemon task handler about to load the checkpoint for a "
        "delivered pk"),
    "daemon.checkpoint.post": (
        "checkpoint loaded and process rematerialized, stepping about "
        "to begin — the canonical kill-9-mid-step moment"),
    "broker.ack.pre": (
        "worker finished a task but has not acked it — a crash here "
        "forces redelivery of an already-completed process"),
    "broker.commit.pre": (
        "broker server about to commit its batched task-table state"),
    "broker.deliver.pre": (
        "broker server delivering one task frame; supports the "
        "'duplicate' directive (same frame sent twice)"),
    "broker.broadcast.pre": (
        "broker server fanning one broadcast batch to one client; "
        "supports the 'drop' directive (a partition)"),
    "lease.expire": (
        "broker reaper (or client drop) expiring a lapsed process lease — "
        "the pk is about to be requeued and its next grant epoch-bumped"),
    "broker.restart": (
        "daemon supervisor about to respawn a dead broker process on its "
        "old port; the replacement rebuilds state from the broker sqlite"),
}

_ACTIONS = ("crash", "raise", "delay", "duplicate", "drop")


class ChaosInjected(RuntimeError):
    """The exception a ``raise`` action throws at a fault point."""


class _Rule:
    __slots__ = ("point", "action", "nth", "prob", "once", "max_fires",
                 "delay", "exit_code", "hits", "fires")

    def __init__(self, point: str, action: str, *, nth: int | None = None,
                 p: float | None = None, once: bool = False,
                 max_fires: int | None = None, delay: float = 0.05,
                 exit_code: int = CRASH_EXIT_CODE):
        if action not in _ACTIONS:
            raise ValueError(f"unknown chaos action {action!r}; "
                             f"expected one of {_ACTIONS}")
        is_pattern = any(ch in point for ch in "*?[")
        if not is_pattern and point not in CATALOG:
            raise ValueError(f"unknown fault point {point!r}; known: "
                             f"{sorted(CATALOG)}")
        if is_pattern and not any(fnmatch.fnmatch(n, point)
                                  for n in CATALOG):
            raise ValueError(f"pattern {point!r} matches no fault point")
        self.point = point
        self.action = action
        self.nth = nth
        self.prob = p
        self.once = once
        self.max_fires = max_fires
        self.delay = delay
        self.exit_code = exit_code
        self.hits = 0
        self.fires = 0

    def matches(self, point: str) -> bool:
        return self.point == point or fnmatch.fnmatch(point, self.point)

    def should_fire(self, rng: random.Random) -> bool:
        self.hits += 1
        if self.once and self.fires:
            return False
        if self.max_fires is not None and self.fires >= self.max_fires:
            return False
        if self.nth is not None:
            return self.hits == self.nth
        if self.prob is not None:
            # one draw per hit keeps the stream deterministic per seed
            return rng.random() < self.prob
        return True

    def spec(self) -> str:
        opts = []
        if self.nth is not None:
            opts.append(f"nth={self.nth}")
        if self.prob is not None:
            opts.append(f"p={self.prob}")
        if self.once:
            opts.append("once")
        if self.max_fires is not None:
            opts.append(f"max={self.max_fires}")
        if self.action == "delay" and self.delay != 0.05:
            opts.append(f"delay={self.delay}")
        if self.exit_code != CRASH_EXIT_CODE:
            opts.append(f"exit={self.exit_code}")
        clause = f"{self.point}:{self.action}"
        return clause + (":" + ",".join(opts) if opts else "")


class ChaosPlan:
    """A seeded set of fault rules. Deterministic: the same plan spec +
    seed makes the same fire/no-fire decisions in the same hit order."""

    def __init__(self, seed: int = 0):
        self.seed = seed
        self.rules: list[_Rule] = []
        self._rng = random.Random(seed)
        #: point -> number of times a rule fired there (any action)
        self.fired: dict[str, int] = {}

    def on(self, point: str, action: str, **kw) -> "ChaosPlan":
        """Add a rule (chainable). Keywords: ``nth``, ``p``, ``once``,
        ``max`` (alias ``max_fires``), ``delay``, ``exit_code``."""
        if "max" in kw:  # mirror the env-spec option name
            kw["max_fires"] = kw.pop("max")
        self.rules.append(_Rule(point, action, **kw))
        return self

    # -- the hot call ------------------------------------------------------
    def hit(self, point: str, ctx: dict) -> str | None:
        directive = None
        for rule in self.rules:
            if not rule.matches(point):
                continue
            if not rule.should_fire(self._rng):
                continue
            rule.fires += 1
            self.fired[point] = self.fired.get(point, 0) + 1
            if rule.action == "crash":
                sys.stderr.write(
                    f"CHAOS: crash at {point} (pid {os.getpid()}, "
                    f"ctx {ctx})\n")
                sys.stderr.flush()
                os._exit(rule.exit_code)
            elif rule.action == "raise":
                raise ChaosInjected(f"chaos: injected failure at {point}")
            elif rule.action == "delay":
                time.sleep(rule.delay)
            else:  # duplicate / drop — cooperative, the seam acts on it
                directive = rule.action
        return directive

    # -- (de)serialization -------------------------------------------------
    def spec(self) -> str:
        return ";".join([f"seed={self.seed}"] +
                        [r.spec() for r in self.rules])

    @classmethod
    def parse(cls, spec: str) -> "ChaosPlan":
        """Parse a ``REPRO_CHAOS`` spec string; see the module docstring
        for the grammar."""
        seed = 0
        clauses = []
        for raw in spec.split(";"):
            raw = raw.strip()
            if not raw:
                continue
            if raw.startswith("seed="):
                seed = int(raw[5:])
            else:
                clauses.append(raw)
        plan = cls(seed=seed)
        for clause in clauses:
            parts = clause.split(":")
            if len(parts) not in (2, 3):
                raise ValueError(f"bad chaos clause {clause!r}; expected "
                                 "point:action[:k=v,...]")
            point, action = parts[0], parts[1]
            kw: dict[str, Any] = {}
            if len(parts) == 3:
                for opt in parts[2].split(","):
                    opt = opt.strip()
                    if not opt:
                        continue
                    if opt == "once":
                        kw["once"] = True
                        continue
                    key, _, val = opt.partition("=")
                    if key == "nth":
                        kw["nth"] = int(val)
                    elif key == "p":
                        kw["p"] = float(val)
                    elif key == "max":
                        kw["max_fires"] = int(val)
                    elif key == "delay":
                        kw["delay"] = float(val)
                    elif key == "exit":
                        kw["exit_code"] = int(val)
                    else:
                        raise ValueError(f"unknown chaos option {opt!r}")
            plan.on(point, action, **kw)
        return plan


# ---------------------------------------------------------------------------
# Module-level activation (the near-zero disabled path)
# ---------------------------------------------------------------------------

_PLAN: ChaosPlan | None = None
_resolved = False


def _resolve() -> ChaosPlan | None:
    global _PLAN, _resolved
    _resolved = True
    spec = os.environ.get(ENV_VAR)
    if spec:
        _PLAN = ChaosPlan.parse(spec)
    return _PLAN


def fault_point(name: str, **ctx: Any) -> str | None:
    """The seam hook. Returns a cooperative directive (``"duplicate"`` /
    ``"drop"``) when a matching rule fired with one, else None; may also
    raise :class:`ChaosInjected`, sleep, or never return (crash).

    Disabled (no plan, no ``REPRO_CHAOS``): one global load, one ``if``,
    one return — safe to leave on every hot path."""
    plan = _PLAN
    if plan is None:
        if _resolved:
            return None
        plan = _resolve()
        if plan is None:
            return None
    return plan.hit(name, ctx)


def activate(plan: ChaosPlan) -> None:
    """Arm a plan in this process (overrides the env)."""
    global _PLAN, _resolved
    _PLAN = plan
    _resolved = True


def deactivate() -> None:
    """Disarm chaos in this process *even if* ``REPRO_CHAOS`` is set —
    the harness calls this so only its spawned workers are armed."""
    global _PLAN, _resolved
    _PLAN = None
    _resolved = True


def reset() -> None:
    """Back to lazy env-resolved state (tests)."""
    global _PLAN, _resolved
    _PLAN = None
    _resolved = False


def active_plan() -> ChaosPlan | None:
    return _PLAN
