"""Chaos subsystem: deterministic fault injection for the engine.

The paper's headline claim is robustness — processes that survive worker
crashes, broker partitions and duplicated deliveries. This package is how
the repo *proves* it instead of asserting it:

* :mod:`repro.chaos.faults` — the fault-point registry. Hot paths in the
  store, the engine and the broker call ``fault_point("<seam>")`` at
  every paper-claimed failure window; a seeded :class:`ChaosPlan`
  (programmatic or via the ``REPRO_CHAOS`` env spec) decides whether a
  hit crashes the process, raises, delays, or asks the seam to
  duplicate/drop a frame. Disabled, a fault point is one module-global
  load and a ``None`` check — cheap enough to stay threaded through the
  hot paths permanently, like the tracer's no-op span.
* :mod:`repro.chaos.harness` — the scenario runner: spawns a real daemon
  (broker + workers as OS processes), kill -9's workers mid-step, crashes
  inside store transactions, partitions broadcast fan-out, duplicates
  task delivery, then supervises restarts until the workload drains.
* :mod:`repro.chaos.invariants` — the post-chaos verifier: zero lost /
  duplicated / resurrected processes and a consistent provenance graph.

Only :mod:`faults` is imported here — the instrumented layers (store,
broker, process) import this package, so it must not pull the engine in.
"""

from repro.chaos.faults import (  # noqa: F401
    CATALOG, ChaosInjected, ChaosPlan, activate, active_plan, deactivate,
    fault_point, reset,
)
