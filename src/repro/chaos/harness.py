"""Chaos scenario runner: real daemon workers, real kill -9, real faults.

Each scenario spawns an actual :class:`repro.engine.daemon.Daemon` (broker
process + worker OS processes), submits checkpoint-heavy workloads, then
hurts the system in a seeded, reproducible way:

* ``REPRO_CHAOS`` is exported before the daemon starts, so every spawned
  child (broker and workers) arms the same deterministic fault plan while
  the harness process itself stays disarmed (`faults.deactivate()`).
* SIGKILLs are scheduled from ``random.Random(seed)`` — same seed, same
  kill times, same victim indices.
* Durable kills follow the CLI pattern: write the ``kill_requested``
  marker first, then best-effort the live RPC.

The daemon supervisor restarts dead workers; after ``heal_restarts``
restarts the harness pops ``REPRO_CHAOS`` from the environment so
replacement workers come up clean and the system can drain. When every
submitted pk is terminal (or the timeout passes), the invariant checker
judges the store.
"""

from __future__ import annotations

import os
import re
import signal
import sqlite3
import random
import tempfile
import time
from dataclasses import dataclass, field

from repro.chaos import faults
from repro.chaos.invariants import InvariantReport, check_store
from repro.engine.runner import TERMINAL

__all__ = ["Scenario", "ScenarioResult", "SCENARIOS", "run_scenario",
           "list_scenarios"]


@dataclass
class Scenario:
    name: str
    description: str
    #: fault clauses (without the seed= prefix); None = no injected faults
    chaos: str | None = None
    workload: str = "calc"  # "calc" | "chain"
    n: int = 4
    steps: int = 4
    pause: float = 0.1
    children: int = 2  # chain workload: children per chain
    workers: int = 2
    slots: int = 10
    #: SIGKILL schedule: ``sigkills`` kills at seeded times in the window
    sigkills: int = 0
    sigkill_window: tuple[float, float] = (0.4, 2.5)
    #: zombie schedule: SIGSTOP one live worker at ``sigstop_at`` (it
    #: keeps its OS pid — the supervisor does NOT restart it), then
    #: SIGCONT it at ``sigcont_at``, after its leases lapsed and its pks
    #: were requeued — the woken zombie must fence itself on the store
    sigstop_at: float | None = None
    sigcont_at: float | None = None
    #: SIGKILL the broker OS process at this offset; the daemon
    #: supervisor must restart it on the same port
    broker_kill_at: float | None = None
    #: durable kill_requested markers written against this many pks
    durable_kills: int = 0
    kill_at: float = 0.4
    #: pop REPRO_CHAOS after this many worker restarts so the system heals
    heal_restarts: int | None = None
    env: dict = field(default_factory=dict)
    timeout: float = 90.0
    #: scenario-level expectations, checked on top of the invariants
    expect_restarts: bool = False
    expect_stats: dict = field(default_factory=dict)
    expect_killed: bool = False
    expect_broker_restarts: bool = False
    #: minimum values for durable store meta counters, e.g.
    #: {"lease.fenced_writes": 1} — proof the fencing actually fired
    expect_meta: dict = field(default_factory=dict)


@dataclass
class ScenarioResult:
    name: str
    seed: int
    workdir: str
    report: InvariantReport
    restarts: int
    broker_stats: dict
    states: dict
    elapsed: float
    failures: list = field(default_factory=list)
    broker_restarts: int = 0
    meta: dict = field(default_factory=dict)

    @property
    def ok(self) -> bool:
        return self.report.ok and not self.failures

    def summary(self) -> str:
        head = "PASS" if self.ok else "FAIL"
        lines = [
            f"scenario {self.name!r} seed={self.seed}: {head} "
            f"({self.elapsed:.1f}s, {self.restarts} restarts, "
            f"{self.broker_restarts} broker restarts)",
            self.report.summary(),
        ]
        for key, val in sorted(self.meta.items()):
            lines.append(f"store meta {key:<18}: {val}")
        for key in ("chaos_duplicated", "chaos_dropped", "clients_dropped",
                    "tasks_delivered", "leases_granted", "leases_expired",
                    "stale_claims"):
            if key in self.broker_stats:
                lines.append(f"broker {key:<17}: {self.broker_stats[key]}")
        for f in self.failures:
            lines.append(f"  - [scenario] {f}")
        return "\n".join(lines)


SCENARIOS: dict[str, Scenario] = {s.name: s for s in [
    Scenario(
        name="kill9-midstep",
        description="SIGKILL live workers mid-step; supervisor restarts "
                    "them and replacements resume from checkpoints.",
        n=6, steps=6, pause=0.15,
        sigkills=3, sigkill_window=(0.5, 2.5),
        expect_restarts=True),
    Scenario(
        name="crash-in-txn",
        description="Worker dies inside a store transaction (just before "
                    "commit); WAL rollback + redelivery must leave no "
                    "half-written provenance.",
        chaos="store.commit.pre:crash:nth=3",
        n=4, steps=5, pause=0.1,
        heal_restarts=2, expect_restarts=True),
    Scenario(
        name="crash-before-ack",
        description="Worker finishes a process (terminal state durable) "
                    "but dies before acking the task; the redelivered "
                    "task must be recognised as already finished.",
        chaos="broker.ack.pre:crash:nth=1",
        n=3, steps=3, pause=0.08,
        heal_restarts=2, expect_restarts=True),
    Scenario(
        name="dup-delivery",
        description="Broker hands the same task frame over twice "
                    "(at-least-once transport); outputs must still land "
                    "exactly once.",
        chaos="broker.deliver.pre:duplicate:nth=2;"
              "broker.deliver.pre:duplicate:p=0.4,max=4",
        n=6, steps=3, pause=0.08,
        expect_stats={"chaos_duplicated": 1}),
    Scenario(
        name="broker-partition",
        description="Terminal broadcasts dropped while workchain parents "
                    "wait on children; the liveness re-check must wake "
                    "the parents anyway.",
        chaos="broker.broadcast.pre:drop:nth=1;"
              "broker.broadcast.pre:drop:p=0.5,max=5",
        workload="chain", n=2, steps=3, pause=0.08, children=2,
        env={"REPRO_LIVENESS_INTERVAL": "1.0"},
        expect_stats={"chaos_dropped": 1}),
    Scenario(
        name="kill-during-crash",
        description="Durable kill requests race worker crashes; the kill "
                    "marker must survive the restart and land.",
        chaos="process.flush.post:crash:nth=4",
        n=4, steps=10, pause=0.2,
        durable_kills=2, kill_at=0.4,
        heal_restarts=2, expect_restarts=True, expect_killed=True),
    Scenario(
        name="slow-io",
        description="Injected latency on store and broker commits; "
                    "everything still completes, just slower.",
        chaos="store.commit.pre:delay:delay=0.03,p=0.5;"
              "broker.commit.pre:delay:delay=0.02,p=0.3",
        n=4, steps=3, pause=0.05),
    Scenario(
        name="zombie-worker",
        description="SIGSTOP a live worker across lease expiry (a GC "
                    "pause / partition stand-in); its pks are requeued at "
                    "a bumped epoch, and when SIGCONT wakes the zombie "
                    "its stale writes must be fenced by the store — "
                    "outputs land exactly once, from the new holder.",
        n=4, steps=6, pause=0.25, workers=2,
        sigstop_at=0.8, sigcont_at=3.5,
        expect_stats={"leases_expired": 1},
        expect_meta={"lease.fenced_writes": 1}),
    Scenario(
        name="broker-kill9",
        description="kill -9 the broker mid-delivery; the daemon "
                    "supervisor restarts it on the same port, the "
                    "replacement rebuilds leases/tasks from sqlite, "
                    "workers reconnect and re-own — exactly-once holds.",
        n=6, steps=5, pause=0.15, workers=2,
        broker_kill_at=1.0,
        expect_broker_restarts=True),
    Scenario(
        name="fleet-churn",
        description="Rolling SIGKILLs across a 3-worker fleet under "
                    "load; leases expire, epochs advance, replacements "
                    "resume from checkpoints — no duplicated outputs.",
        n=8, steps=5, pause=0.12, workers=3,
        sigkills=5, sigkill_window=(0.5, 4.0),
        expect_restarts=True),
]}


def list_scenarios() -> list[Scenario]:
    return list(SCENARIOS.values())


def _leased_worker_pids(broker_db: str) -> set[int]:
    """OS pids of workers currently holding process leases, parsed from
    the broker's durable lease table (worker names embed the pid). Used
    to pick a SIGSTOP victim that actually owns in-flight work — a
    zombie with nothing to write can never demonstrate fencing.
    Best-effort: the broker batches commits, so this lags grants by up
    to one reaper tick."""
    try:
        conn = sqlite3.connect(broker_db, timeout=0.2)
        try:
            rows = conn.execute(
                "SELECT DISTINCT worker FROM leases"
                " WHERE worker IS NOT NULL").fetchall()
        finally:
            conn.close()
    except sqlite3.Error:
        return set()
    pids = set()
    for (name,) in rows:
        match = re.match(r"worker\.(\d+)-", name or "")
        if match:
            pids.add(int(match.group(1)))
    return pids


def _poll_states(store, pks) -> dict:
    qs = ",".join("?" for _ in pks)
    with store._lock:
        rows = store._conn().execute(
            f"SELECT pk, process_state FROM nodes WHERE pk IN ({qs})",
            list(pks)).fetchall()
    return {r["pk"]: r["process_state"] for r in rows}


def run_scenario(name: str, seed: int = 1,
                 workdir: str | None = None) -> ScenarioResult:
    """Run one named scenario end to end and return its judged result."""
    from repro.chaos.workloads import ChaosCalc, ChaosChain
    from repro.core import Float, Int
    from repro.engine.daemon import Daemon
    from repro.provenance.store import configure_store

    sc = SCENARIOS[name]
    rng = random.Random(seed)
    workdir = workdir or tempfile.mkdtemp(prefix=f"chaos-{name}-")

    # the harness process must never trip its own seams — only spawned
    # daemon children re-resolve the plan from the environment
    faults.deactivate()
    saved_env = {}
    env = dict(sc.env)
    if sc.chaos:
        env[faults.ENV_VAR] = f"seed={seed};{sc.chaos}"
    for key, value in env.items():
        saved_env[key] = os.environ.get(key)
        os.environ[key] = value

    t0 = time.time()
    daemon = Daemon(workdir, workers=sc.workers, slots=sc.slots,
                    heartbeat=0.5)
    restarts = 0
    broker_stats: dict = {}
    states: dict = {}
    failures: list[str] = []
    stopped_pid: int | None = None
    try:
        daemon.start()
        store = configure_store(daemon.store_path)

        pks = []
        for _ in range(sc.n):
            if sc.workload == "chain":
                pks.append(daemon.submit(ChaosChain, {
                    "n": Int(sc.children), "steps": Int(sc.steps),
                    "pause": Float(sc.pause)}))
            else:
                pks.append(daemon.submit(ChaosCalc, {
                    "steps": Int(sc.steps), "pause": Float(sc.pause)}))

        # seeded schedules, fixed before the loop: reproducibility means
        # the same seed produces the same kill times and victims
        lo, hi = sc.sigkill_window
        sigkill_plan = sorted(
            (t0 + rng.uniform(lo, hi), rng.randrange(1000))
            for _ in range(sc.sigkills))
        kill_pks = rng.sample(pks, sc.durable_kills) if sc.durable_kills else []
        kill_deadline = t0 + sc.kill_at
        kills_done = False
        armed = sc.chaos is not None
        stop_deadline = (t0 + sc.sigstop_at
                         if sc.sigstop_at is not None else None)
        cont_deadline = (t0 + sc.sigcont_at
                         if sc.sigcont_at is not None else None)
        broker_kill_deadline = (t0 + sc.broker_kill_at
                                if sc.broker_kill_at is not None else None)

        deadline = t0 + sc.timeout
        pending = set(pks)
        while time.time() < deadline:
            restarts += daemon.supervise()
            if (armed and sc.heal_restarts is not None
                    and restarts >= sc.heal_restarts):
                # replacement workers from here on spawn clean — the
                # system must now drain to quiescence
                os.environ.pop(faults.ENV_VAR, None)
                armed = False
            now = time.time()
            while sigkill_plan and now >= sigkill_plan[0][0]:
                _, victim = sigkill_plan.pop(0)
                live = daemon.worker_pids()
                if live and pending:
                    os.kill(live[victim % len(live)], signal.SIGKILL)
            if (stop_deadline is not None and stopped_pid is None
                    and now >= stop_deadline):
                # only stop a worker that holds a lease: under load
                # workers can spawn slowly, and a victim with no
                # in-flight work has no stale write to fence — defer to
                # the next tick until one qualifies
                leased = _leased_worker_pids(daemon.broker_db)
                victims = [pid for pid in daemon.worker_pids()
                           if pid in leased]
                if victims:
                    # the victim keeps its pid (is_alive() stays True, no
                    # supervisor restart) — only the broker reaper notices
                    stopped_pid = victims[0]
                    os.kill(stopped_pid, signal.SIGSTOP)
                    if sc.sigcont_at is not None:
                        # hold the zombie for the scenario's window
                        # measured from the ACTUAL stop — slow startup
                        # must not shrink the lease-expiry window
                        cont_deadline = time.time() + (sc.sigcont_at
                                                       - sc.sigstop_at)
            if (cont_deadline is not None and stopped_pid is not None
                    and now >= cont_deadline):
                os.kill(stopped_pid, signal.SIGCONT)
                cont_deadline = None
            if broker_kill_deadline is not None and now >= broker_kill_deadline:
                broker_kill_deadline = None
                proc = daemon._broker_proc
                if proc is not None and proc.is_alive():
                    os.kill(proc.pid, signal.SIGKILL)
            if kill_pks and not kills_done and now >= kill_deadline:
                kills_done = True
                from repro.engine.controller import ProcessController
                controller = ProcessController(daemon.host, daemon.port,
                                               timeout=2.0)
                for pk in kill_pks:
                    # durable-first (CLI pattern): marker lands even if no
                    # worker currently owns the process
                    store.update_process(
                        pk, attributes={"kill_requested": "chaos kill"})
                    try:
                        controller.kill(pk, "chaos kill")
                    except Exception:  # noqa: BLE001 - worker may be dead
                        pass
            states = _poll_states(store, pks)
            pending = {pk for pk in pks
                       if states.get(pk) not in TERMINAL}
            if not pending:
                if stopped_pid is not None and cont_deadline is not None:
                    # the fleet drained before the scheduled wake-up: wake
                    # the zombie NOW — the scenario's point is what it does
                    # next (its stale writes must fence), so it needs to be
                    # running before teardown
                    os.kill(stopped_pid, signal.SIGCONT)
                    cont_deadline = None
                if sc.expect_meta and not all(
                        int(store.get_meta(key) or 0) >= minimum
                        for key, minimum in sc.expect_meta.items()):
                    time.sleep(0.1)  # zombie awake, fence not recorded yet
                    continue
                break
            time.sleep(0.25)

        if pending:
            failures.append(
                f"timeout: {len(pending)} of {len(pks)} processes never "
                f"reached a terminal state: {sorted(pending)}")
        try:
            broker_stats = daemon._submitter().broker_stats()
        except Exception:  # noqa: BLE001 - broker may have been killed
            broker_stats = {}
    finally:
        if stopped_pid is not None:
            try:  # never leave a SIGSTOPped child behind
                os.kill(stopped_pid, signal.SIGCONT)
            except OSError:
                pass
        daemon.stop()
        for key, value in saved_env.items():
            if value is None:
                os.environ.pop(key, None)
            else:
                os.environ[key] = value
        faults.reset()

    # judge: global invariants first, then scenario-level expectations
    report = check_store(store, expected_pks=pks)
    if sc.expect_restarts and restarts < 1:
        failures.append("expected at least one worker restart; saw none")
    if sc.expect_broker_restarts and daemon.broker_restarts < 1:
        failures.append("expected the supervisor to restart the broker; "
                        "it never did")
    for key, minimum in sc.expect_stats.items():
        if broker_stats.get(key, 0) < minimum:
            failures.append(
                f"expected broker stat {key} >= {minimum}, "
                f"got {broker_stats.get(key, 0)}")
    meta = {key: int(store.get_meta(key) or 0) for key in sc.expect_meta}
    for key, minimum in sc.expect_meta.items():
        if meta.get(key, 0) < minimum:
            failures.append(
                f"expected store meta {key} >= {minimum}, "
                f"got {meta.get(key, 0)}")
    if sc.expect_killed:
        killed = [pk for pk in kill_pks if states.get(pk) == "killed"]
        if not killed:
            failures.append(
                f"expected durably-killed pks {kill_pks} to end in state "
                f"'killed'; states: { {pk: states.get(pk) for pk in kill_pks} }")

    return ScenarioResult(
        name=name, seed=seed, workdir=workdir, report=report,
        restarts=restarts, broker_stats=broker_stats, states=states,
        elapsed=time.time() - t0, failures=failures,
        broker_restarts=daemon.broker_restarts, meta=meta)
