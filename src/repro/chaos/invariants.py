"""Post-chaos provenance invariant checker (the paper's robustness claim,
made checkable).

After any amount of fault injection — kill -9 mid-step, crashes inside
store transactions, dropped broker frames, duplicated deliveries — the
provenance store must still satisfy a fixed set of invariants. A scenario
"passes" only if every one of them holds:

1. **No lost processes** — every submitted pk exists and (once the system
   quiesces) is in a terminal state.
2. **No resurrected processes** — a process's recorded state history never
   contains an entry after a terminal state.
3. **Terminal ⇒ no checkpoint** — the terminal transaction removes the
   checkpoint atomically with the final state; a terminal node with a
   checkpoint means that transaction tore.
4. **Outputs exactly once** — no output label emitted twice by the same
   process, no data node created by two processes, no child called by two
   parents (the duplicated-delivery scenarios aim squarely at this).
5. **Referential integrity** — every link endpoint is an existing node.
6. **Monotone history** — state-history timestamps are non-decreasing
   (small tolerance for cross-worker clock jitter).
7. **Finished ⇒ exit_status recorded**; **kill_requested ⇒ terminal**.

All checks run as raw SQL/JSON over the store — independent of the engine
code paths whose correctness they judge.
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field

from repro.engine.runner import TERMINAL

STATE_HISTORY_ATTR = "state_history"

#: allowed backwards clock drift between consecutive history entries
#: (entries are stamped by different OS processes across restarts)
_CLOCK_TOLERANCE = 0.25


@dataclass
class Violation:
    invariant: str
    pk: int | None
    detail: str

    def __str__(self) -> str:  # pragma: no cover - formatting
        where = f"pk={self.pk}: " if self.pk is not None else ""
        return f"[{self.invariant}] {where}{self.detail}"


@dataclass
class InvariantReport:
    violations: list[Violation] = field(default_factory=list)
    checked_processes: int = 0
    checked_links: int = 0
    expected: int = 0
    states: dict[str, int] = field(default_factory=dict)

    @property
    def ok(self) -> bool:
        return not self.violations

    def add(self, invariant: str, pk: int | None, detail: str) -> None:
        self.violations.append(Violation(invariant, pk, detail))

    def summary(self) -> str:
        lines = [
            f"processes checked : {self.checked_processes}"
            + (f" (expected {self.expected})" if self.expected else ""),
            f"links checked     : {self.checked_links}",
            "states            : " + ", ".join(
                f"{k}={v}" for k, v in sorted(self.states.items())),
            f"violations        : {len(self.violations)}",
        ]
        for v in self.violations[:50]:
            lines.append(f"  - {v}")
        if len(self.violations) > 50:
            lines.append(f"  ... and {len(self.violations) - 50} more")
        return "\n".join(lines)


def check_store(store, expected_pks=None, *,
                expect_terminal: bool = True) -> InvariantReport:
    """Run every invariant against ``store``. ``expected_pks`` are the
    processes the caller submitted (lost-process detection); with
    ``expect_terminal`` they must also have reached a terminal state."""
    report = InvariantReport()
    expected = sorted(set(expected_pks or ()))
    report.expected = len(expected)
    with store._lock:
        conn = store._conn()

        # -- process census -------------------------------------------------
        rows = conn.execute(
            "SELECT pk, node_type, process_state, exit_status, checkpoint,"
            " attributes FROM nodes WHERE node_type LIKE 'process%'"
        ).fetchall()
        procs = {r["pk"]: r for r in rows}
        report.checked_processes = len(procs)
        for row in rows:
            state = row["process_state"] or "?"
            report.states[state] = report.states.get(state, 0) + 1

        # 1. no lost processes
        for pk in expected:
            row = procs.get(pk)
            if row is None:
                report.add("lost", pk, "submitted process has no node")
            elif expect_terminal and row["process_state"] not in TERMINAL:
                report.add("lost", pk,
                           f"not terminal: state={row['process_state']!r}")

        for pk, row in procs.items():
            state = row["process_state"]
            terminal = state in TERMINAL

            # 3. terminal ⇒ checkpoint removed
            if terminal and row["checkpoint"] is not None:
                report.add("terminal-checkpoint", pk,
                           f"state={state!r} but checkpoint survives")

            # 7a. finished ⇒ exit_status recorded
            if state == "finished" and row["exit_status"] is None:
                report.add("exit-status", pk, "finished with NULL exit_status")

            try:
                attrs = json.loads(row["attributes"] or "{}")
            except ValueError:
                report.add("attributes", pk, "attributes not valid JSON")
                continue

            # 7b. durably-requested kill must not be outrun
            if attrs.get("kill_requested") is not None and not terminal:
                report.add("kill-durability", pk,
                           f"kill requested but state={state!r}")

            # 2 + 6. state history: monotone, nothing after terminal
            history = attrs.get(STATE_HISTORY_ATTR) or []
            seen_terminal = None
            last_ts = None
            for entry in history:
                st, ts = entry[0], entry[1]
                if seen_terminal is not None:
                    report.add("resurrected", pk,
                               f"state {st!r} recorded after terminal "
                               f"{seen_terminal!r}")
                    break
                if st in TERMINAL:
                    seen_terminal = st
                if last_ts is not None and ts < last_ts - _CLOCK_TOLERANCE:
                    report.add("history-monotone", pk,
                               f"timestamp regressed {last_ts:.3f} -> {ts:.3f}")
                last_ts = ts
            if terminal and history and seen_terminal is None:
                report.add("resurrected", pk,
                           f"state={state!r} but history never records a "
                           "terminal entry")

        # -- link integrity ------------------------------------------------
        report.checked_links = conn.execute(
            "SELECT COUNT(*) AS n FROM links").fetchone()["n"]

        # 5. every endpoint exists
        for col in ("in_id", "out_id"):
            for row in conn.execute(
                    f"SELECT l.{col} AS pk, l.link_type FROM links l "
                    f"LEFT JOIN nodes n ON n.pk = l.{col} "
                    "WHERE n.pk IS NULL").fetchall():
                report.add("dangling-link", row["pk"],
                           f"{row['link_type']} link references missing "
                           f"node via {col}")

        # 4a. same process emits the same output label twice
        for row in conn.execute(
                "SELECT in_id, link_type, label, COUNT(*) AS n FROM links "
                "WHERE link_type IN ('create', 'return') "
                "GROUP BY in_id, link_type, label HAVING n > 1").fetchall():
            report.add("duplicate-output", row["in_id"],
                       f"{row['link_type']} link {row['label']!r} emitted "
                       f"{row['n']} times")

        # 4b. a data node created by more than one process
        for row in conn.execute(
                "SELECT out_id, COUNT(*) AS n FROM links "
                "WHERE link_type = 'create' "
                "GROUP BY out_id HAVING n > 1").fetchall():
            report.add("duplicate-create", row["out_id"],
                       f"data node created by {row['n']} processes")

        # 4c. a child process called by more than one parent
        for row in conn.execute(
                "SELECT out_id, COUNT(*) AS n FROM links "
                "WHERE link_type IN ('call_calc', 'call_work') "
                "GROUP BY out_id HAVING n > 1").fetchall():
            report.add("duplicate-call", row["out_id"],
                       f"process called by {row['n']} parents")

    return report
