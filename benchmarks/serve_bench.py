"""Continuous-batching LM serving under a seeded Zipfian prompt workload.

Two sections, one engine (reduced ``aiida-demo-110m`` decoding through the
Pallas flash-decode kernel, interpreted on CPU):

* **scheduler** — drive the :class:`~repro.serving.serve.BatchScheduler`
  directly with all-distinct prompts: raw continuous-batching throughput
  (tokens/s) with slot eviction + FIFO re-admission mid-flight;
* **cached serving** — replay a Zipf-distributed request stream through
  the :func:`repro.serving.inference.generate` calcfunction against one
  provenance store with caching enabled. Repeated prompts must resolve on
  the content-addressed fast path: the ``serving.decode_steps`` counter
  does not move for a hit, which is how hits are detected and asserted.

``--smoke`` shrinks everything for CI and exits non-zero unless (a) a
repeated prompt is served with zero decode steps and (b) scheduler
tokens/s > 0. A full run writes ``BENCH_serve.json``.

    PYTHONPATH=src python -m benchmarks.serve_bench --requests 80
    PYTHONPATH=src python -m benchmarks.serve_bench --smoke
"""

from __future__ import annotations

import argparse
import json
import sys
import time

sys.path.insert(0, "src")

import numpy as np

ARCH = "aiida-demo-110m"


def zipf_indices(rng: np.random.Generator, n_requests: int, pool: int,
                 a: float) -> np.ndarray:
    """Zipf-by-rank over a finite pool: P(rank r) ~ 1/r^a, r = 1..pool."""
    w = 1.0 / np.arange(1, pool + 1, dtype=np.float64) ** a
    return rng.choice(pool, size=n_requests, p=w / w.sum())


def make_prompt_pool(rng: np.random.Generator, pool: int, prompt_len: int,
                     vocab: int) -> list[list[int]]:
    return [rng.integers(1, vocab, prompt_len).tolist() for _ in range(pool)]


def bench_scheduler(seed: int, n_requests: int, prompt_len: int,
                    new_tokens: int, batch: int) -> dict:
    """Raw continuous-batching throughput: all-distinct prompts, more
    requests than slots, so eviction/re-admission happens mid-flight."""
    from repro.observability.metrics import get_registry
    from repro.serving.inference import get_engine, reset_engines

    reset_engines()
    eng = get_engine(ARCH, seed, need_len=prompt_len + new_tokens,
                     batch_size=batch)
    rng = np.random.default_rng(seed)
    prompts = make_prompt_pool(rng, n_requests, prompt_len,
                               eng.cfg.vocab_size)
    # warm the compile caches (prefill at this length + the decode step)
    eng.generate_many([prompts[0]], 2)

    steps0 = get_registry().counter("serving.decode_steps").value
    t0 = time.perf_counter()
    reqs = eng.generate_many(prompts, new_tokens)
    dt = time.perf_counter() - t0
    steps = get_registry().counter("serving.decode_steps").value - steps0

    toks = sum(len(r.generated) for r in reqs)
    assert all(r.done for r in reqs)
    return {
        "requests": n_requests,
        "batch_size": batch,
        "prompt_len": prompt_len,
        "new_tokens": new_tokens,
        "decode_steps": int(steps),
        "tokens_generated": int(toks),
        "wall_seconds": round(dt, 4),
        "tokens_per_s": round(toks / dt, 2),
    }


def bench_cached_serving(seed: int, n_requests: int, pool: int,
                         prompt_len: int, new_tokens: int,
                         zipf_a: float) -> dict:
    """Zipfian request stream through the generate() calcfunction with the
    content-addressed cache on; hits are calls that ran zero decode steps."""
    from repro.caching import enable_caching
    from repro.core.datatypes import ArrayData, Int, Str
    from repro.engine.runner import Runner, set_default_runner
    from repro.observability.metrics import get_registry
    from repro.provenance.store import configure_store
    from repro.serving.inference import generate, reset_engines

    store = configure_store(":memory:")
    runner = Runner(store=store)
    set_default_runner(runner)
    reset_engines()

    rng = np.random.default_rng(seed)
    from repro.configs import reduced_config
    vocab = reduced_config(ARCH).vocab_size
    prompts = make_prompt_pool(rng, pool, prompt_len, vocab)
    stream = zipf_indices(rng, n_requests, pool, zipf_a)

    decode_steps = get_registry().counter("serving.decode_steps")
    hits = 0
    toks = 0
    results: dict[int, list[int]] = {}
    t0 = time.perf_counter()
    with enable_caching():
        for idx in stream:
            before = decode_steps.value
            out = generate(Str(ARCH), ArrayData(np.asarray(prompts[idx],
                                                           np.int32)),
                           Int(new_tokens), Int(seed), Int(-1))
            got = [int(t) for t in np.asarray(out["tokens"].value)]
            if decode_steps.value == before:
                hits += 1
                assert results[int(idx)] == got, \
                    f"cache hit for prompt {idx} returned different tokens"
            else:
                results.setdefault(int(idx), got)
            toks += len(got)
    dt = time.perf_counter() - t0

    distinct = len(set(int(i) for i in stream))
    return {
        "requests": n_requests,
        "prompt_pool": pool,
        "distinct_prompts_drawn": distinct,
        "zipf_a": zipf_a,
        "new_tokens": new_tokens,
        "cache_hits": hits,
        "cache_hit_rate": round(hits / n_requests, 4),
        "expected_hit_rate": round(1.0 - distinct / n_requests, 4),
        "tokens_served": int(toks),
        "wall_seconds": round(dt, 4),
        "tokens_per_s": round(toks / dt, 2),
    }


def assert_hit_fast_path(seed: int) -> None:
    """The --smoke acceptance check: the SECOND occurrence of a prompt runs
    zero decode steps and returns identical tokens."""
    from repro.caching import enable_caching
    from repro.core.datatypes import ArrayData, Int, Str
    from repro.engine.runner import Runner, set_default_runner
    from repro.observability.metrics import get_registry
    from repro.provenance.store import configure_store
    from repro.serving.inference import generate, reset_engines

    store = configure_store(":memory:")
    set_default_runner(Runner(store=store))
    reset_engines()

    prompt = ArrayData(np.asarray([7, 11, 13, 17, 19, 23], np.int32))
    decode_steps = get_registry().counter("serving.decode_steps")
    with enable_caching():
        cold = generate(Str(ARCH), prompt, Int(5), Int(seed), Int(-1))
        before = decode_steps.value
        hot = generate(Str(ARCH), prompt, Int(5), Int(seed), Int(-1))
    ran = decode_steps.value - before
    same = np.array_equal(np.asarray(cold["tokens"].value),
                          np.asarray(hot["tokens"].value))
    print(f"repeat-prompt fast path: decode steps on 2nd call = {ran}, "
          f"tokens identical = {same}")
    if ran != 0 or not same:
        print("FAIL: cache-hit fast path did not fire", file=sys.stderr)
        sys.exit(1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes + hard asserts for CI; no json output")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--requests", type=int, default=80)
    ap.add_argument("--pool", type=int, default=12,
                    help="distinct prompts in the Zipf pool")
    ap.add_argument("--zipf-a", type=float, default=1.3)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args()

    if args.smoke:
        args.requests, args.pool = 10, 3
        args.new_tokens, args.prompt_len = 4, 6

    sched = bench_scheduler(args.seed, max(args.requests // 4, args.batch + 2),
                            args.prompt_len, args.new_tokens, args.batch)
    print(f"scheduler: {sched['requests']} reqs through "
          f"{sched['batch_size']} slots -> {sched['tokens_generated']} tok "
          f"in {sched['wall_seconds']}s ({sched['tokens_per_s']} tok/s, "
          f"{sched['decode_steps']} decode steps)")

    served = bench_cached_serving(args.seed, args.requests, args.pool,
                                  args.prompt_len, args.new_tokens,
                                  args.zipf_a)
    print(f"cached serving: {served['requests']} reqs over "
          f"{served['prompt_pool']}-prompt Zipf(a={served['zipf_a']}) pool "
          f"-> hit rate {served['cache_hit_rate']} "
          f"(expected {served['expected_hit_rate']}), "
          f"{served['tokens_per_s']} tok/s")

    if args.smoke:
        assert_hit_fast_path(args.seed)
        ok = (sched["tokens_per_s"] > 0
              and served["cache_hit_rate"] == served["expected_hit_rate"])
        print("smoke:", "PASS" if ok else "FAIL")
        if not ok:
            sys.exit(1)
        return

    payload = {
        "bench": "serve",
        "arch": ARCH,
        "seed": args.seed,
        "scheduler": sched,
        "cached_serving": served,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
