"""Provenance-store hot-path benchmarks (ISSUE 5).

Three metrics, mirroring the criterion-(v) cost model of the paper
(provenance must stay cheap to *write* during execution and cheap to
*traverse* afterwards):

  S1 raw write throughput      — data nodes + links per second into a
                                 file-backed store (the daemon-worker
                                 write path; every row used to cost one
                                 sqlite commit)
  S2 provenance overhead       — engine_bench B3 methodology: a tracked
                                 @calcfunction call vs the bare python
                                 call, on a file-backed profile; the
                                 per-process overhead is what a
                                 high-throughput user pays for provenance
  S3 closure traversal         — compute_closure over a 10k-node
                                 calc/data chain whose data nodes carry
                                 array payloads (the archive-export and
                                 cache-ancestry read path; N+1 row reads
                                 used to drag every payload through the
                                 row cache)

Usage:
    python benchmarks/store_bench.py --label baseline -o BENCH_store.json
    python benchmarks/store_bench.py --label result   -o BENCH_store.json
    python benchmarks/store_bench.py --smoke          # small N + assertions

The json file accumulates one entry per label, so the pre-PR baseline and
the post-PR result live side by side with their speedups.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro.provenance.store import (  # noqa: E402
    LinkType, NodeType, ProvenanceStore,
)


# ---------------------------------------------------------------------------
# S1: write throughput
# ---------------------------------------------------------------------------

def bench_write_throughput(n: int = 2000) -> dict:
    """Store n Int data nodes, each INPUT-linked to a process node."""
    from repro.core.datatypes import Int

    with tempfile.TemporaryDirectory() as tmp:
        store = ProvenanceStore(os.path.join(tmp, "bench.db"))
        proc_pk = store.create_process_node(
            NodeType.CALC_FUNCTION, "bench_sink")
        t0 = time.perf_counter()
        if hasattr(store, "store_data_many"):
            # post-overhaul bulk path: one executemany + one commit
            chunk = 500
            for base in range(0, n, chunk):
                values = [Int(i) for i in range(base, min(base + chunk, n))]
                store.store_data_many(values)
                store.add_links([(v.pk, proc_pk, LinkType.INPUT_CALC,
                                  f"x{v.value}") for v in values])
        else:
            for i in range(n):
                v = store.store_data(Int(i))
                store.add_link(v.pk, proc_pk, LinkType.INPUT_CALC, f"x{i}")
        dt = time.perf_counter() - t0
        store.close()
    return {"name": "write_throughput", "n": n,
            "writes_per_s": round(2 * n / dt, 1),
            "us_per_row": round(dt / (2 * n) * 1e6, 2)}


# ---------------------------------------------------------------------------
# S2: provenance overhead per process (engine_bench B3 methodology)
# ---------------------------------------------------------------------------

def bench_provenance_overhead(n: int = 200) -> dict:
    """Tracked @calcfunction vs bare python call, file-backed store."""
    from repro.core import Int, calcfunction
    from repro.engine.runner import Runner, set_default_runner
    from repro.provenance.store import configure_store

    def bare(a, b):
        return a + b

    @calcfunction
    def tracked(a, b):
        return a + b

    with tempfile.TemporaryDirectory() as tmp:
        store = configure_store(os.path.join(tmp, "bench.db"))
        runner = Runner(store=store)
        set_default_runner(runner)
        try:
            t0 = time.perf_counter()
            for i in range(n):
                bare(i, i)
            t_bare = (time.perf_counter() - t0) / n

            tracked(Int(0), Int(0))  # warm import/spec caches
            commits0 = _commit_count(store)
            t0 = time.perf_counter()
            for i in range(1, n + 1):
                tracked(Int(i), Int(i))
            t_tracked = (time.perf_counter() - t0) / n
            commits = _commit_count(store)
        finally:
            set_default_runner(None)
            store.close()

    out = {"name": "provenance_overhead", "n": n,
           "bare_us": round(t_bare * 1e6, 2),
           "tracked_us": round(t_tracked * 1e6, 1),
           "overhead_us_per_process": round((t_tracked - t_bare) * 1e6, 1)}
    if commits is not None and commits0 is not None:
        out["commits_per_process"] = round((commits - commits0) / n, 2)
    return out


def _commit_count(store) -> int | None:
    """The store's commit counter, when this build exposes one."""
    stats = getattr(store, "stats", None)
    if isinstance(stats, dict):
        return stats.get("commits")
    return None


# ---------------------------------------------------------------------------
# S3: closure traversal over a 10k-node graph
# ---------------------------------------------------------------------------

def _build_chain(store: ProvenanceStore, n_nodes: int) -> int:
    """data -> calc -> data -> calc ... chain; returns the final data pk.

    Every data node carries a real array payload so the traversal cost
    includes what `SELECT *` row reads would drag through the cache.
    """
    from repro.core.datatypes import ArrayData

    arr = np.arange(256, dtype=np.float64)
    prev = store.store_data(ArrayData(arr))
    made = 1
    while made < n_nodes:
        calc_pk = store.create_process_node(
            NodeType.CALC_FUNCTION, "chain_step")
        store.add_link(prev.pk, calc_pk, LinkType.INPUT_CALC, "x")
        nxt = store.store_data(ArrayData(arr + made))
        store.add_link(calc_pk, nxt.pk, LinkType.CREATE, "result")
        prev = nxt
        made += 2
    return prev.pk


def bench_closure_traversal(n_nodes: int = 10000) -> dict:
    from repro.provenance.archive import compute_closure

    store = ProvenanceStore(":memory:")
    tip_pk = _build_chain(store, n_nodes)
    t0 = time.perf_counter()
    closure = compute_closure(store, [tip_pk])
    dt = time.perf_counter() - t0
    assert len(closure) >= n_nodes - 1, (len(closure), n_nodes)
    store.close()
    return {"name": "closure_traversal", "n_nodes": n_nodes,
            "seconds": round(dt, 4),
            "nodes_per_s": round(len(closure) / dt, 1)}


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def run_all(write_n: int, overhead_n: int, closure_n: int) -> dict:
    results = {}
    for fn, args in ((bench_write_throughput, (write_n,)),
                     (bench_provenance_overhead, (overhead_n,)),
                     (bench_closure_traversal, (closure_n,))):
        r = fn(*args)
        results[r.pop("name")] = r
        print(f"  {fn.__name__}: {json.dumps(r)}")
    return results


def _speedups(baseline: dict, result: dict) -> dict:
    out = {}
    try:
        out["write_throughput"] = round(
            result["write_throughput"]["writes_per_s"] /
            baseline["write_throughput"]["writes_per_s"], 2)
        out["provenance_overhead"] = round(
            baseline["provenance_overhead"]["overhead_us_per_process"] /
            result["provenance_overhead"]["overhead_us_per_process"], 2)
        out["closure_traversal"] = round(
            baseline["closure_traversal"]["seconds"] /
            result["closure_traversal"]["seconds"], 2)
    except (KeyError, ZeroDivisionError):
        pass
    return out


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--label", default="result",
                    help="entry name in the output json (baseline/result)")
    ap.add_argument("-o", "--out", default=None,
                    help="json file to merge results into")
    ap.add_argument("--smoke", action="store_true",
                    help="small N + assert the provenance-overhead bar")
    args = ap.parse_args(argv)

    if args.smoke:
        print("store_bench smoke (small N):")
        results = run_all(write_n=300, overhead_n=40, closure_n=2000)
        # deterministic bar: the engine unit-of-work must not fall back to
        # commit-per-call (~12 commits/process on the seed store)
        cpp = results["provenance_overhead"].get("commits_per_process")
        assert cpp is not None and cpp <= 3.0, \
            f"B3 bar: {cpp} commits/process (want <= 3; seed was ~12)"
        # generous wall-clock bar for slow CI machines
        ohd = results["provenance_overhead"]["overhead_us_per_process"]
        assert ohd < 20000, f"B3 bar: overhead {ohd}us/process >= 20ms"
        print(f"smoke OK: {cpp} commits/process, {ohd}us overhead")
        return

    print(f"store_bench [{args.label}]:")
    results = run_all(write_n=2000, overhead_n=200, closure_n=10000)
    if args.out:
        doc = {}
        if os.path.exists(args.out):
            with open(args.out) as fh:
                doc = json.load(fh)
        doc[args.label] = results
        if "baseline" in doc and args.label != "baseline":
            doc["speedups_vs_baseline"] = _speedups(doc["baseline"], results)
        with open(args.out, "w") as fh:
            json.dump(doc, fh, indent=1, sort_keys=True)
            fh.write("\n")
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
