"""Engine benchmarks mirroring the paper's claims.

One function per claim ("table"):
  B1 engine process throughput vs slots (vertical scaling, fig. 5)
  B2 daemon worker scaling (horizontal scaling, fig. 5)
  B3 provenance overhead per process (criterion (v))
  B4 event-driven wake-up vs polling latency (§III.A)
  B5 transport-queue + job-manager bundling (connection/query counts)
  B6 robustness: completion under fault injection (backoff, §II.B.4.a)
  B7 checkpoint save/restore throughput (engine + tensor level)
  B8 remote terminal-notification latency through the broker (§III.C):
     Runner.wait unblocks at event-delivery time, not a poll interval
"""

from __future__ import annotations

import asyncio
import sys
import tempfile
import time


def _fresh_runner(slots=200):
    from repro.engine.runner import Runner, set_default_runner
    from repro.provenance.store import configure_store

    store = configure_store(":memory:")
    runner = Runner(store=store, slots=slots)
    set_default_runner(runner)
    return runner, store


class _NoopChain:
    _cls = None

    @classmethod
    def get(cls):
        if cls._cls is None:
            from repro.core import Int, WorkChain

            class Noop(WorkChain):
                @classmethod
                def define(klass, spec):
                    super(Noop, klass).define(spec)
                    spec.input("n", valid_type=Int, default=Int(0))
                    spec.output("r", valid_type=Int)
                    spec.outline(klass.go)

                def go(self):
                    self.out("r", Int(self.inputs["n"].value + 1))

            cls._cls = Noop
        return cls._cls


def bench_engine_throughput(n_processes=200, slots=100):
    """B1: processes/second through one runner (event loop + provenance)."""
    runner, store = _fresh_runner(slots)
    Noop = _NoopChain.get()
    from repro.core import Int

    async def main():
        t0 = time.perf_counter()
        handles = [runner.submit(Noop, {"n": Int(i)})
                   for i in range(n_processes)]
        for h in handles:
            await h.process.wait_done()
        return time.perf_counter() - t0

    elapsed = runner.loop.run_until_complete(main())
    per = elapsed / n_processes
    return {"name": "engine_throughput",
            "us_per_call": per * 1e6,
            "derived": f"{n_processes/elapsed:.0f} proc/s @ {slots} slots"}


def bench_slot_scaling():
    """B1b: throughput at different slot counts (vertical axis of fig 5)."""
    rows = []
    for slots in (1, 10, 100):
        runner, _ = _fresh_runner(slots)
        Noop = _NoopChain.get()
        from repro.core import Int

        async def main():
            t0 = time.perf_counter()
            hs = [runner.submit(Noop, {"n": Int(i)}) for i in range(100)]
            for h in hs:
                await h.process.wait_done()
            return time.perf_counter() - t0

        dt = runner.loop.run_until_complete(main())
        rows.append((slots, 100 / dt))
    derived = "; ".join(f"{s} slots={r:.0f}/s" for s, r in rows)
    return {"name": "slot_scaling", "us_per_call": 1e6 / rows[-1][1],
            "derived": derived}


def bench_provenance_overhead(n=300):
    """B3: calcfunction call vs bare python call."""
    runner, store = _fresh_runner()
    from repro.core import Int, calcfunction

    def bare(a, b):
        return a + b

    @calcfunction
    def tracked(a, b):
        return a + b

    t0 = time.perf_counter()
    for i in range(n):
        bare(i, i)
    t_bare = time.perf_counter() - t0

    t0 = time.perf_counter()
    for i in range(n):
        tracked(Int(i), Int(i))
    t_tracked = (time.perf_counter() - t0) / n
    nodes = store.count_nodes()
    return {"name": "provenance_overhead",
            "us_per_call": t_tracked * 1e6,
            "derived": f"bare={t_bare/n*1e6:.1f}us; "
                       f"{nodes} nodes stored; "
                       f"overhead={t_tracked*1e6:.0f}us/process"}


def bench_event_vs_poll_latency(n=20):
    """B4: parent wake-up latency after child terminates — event-driven
    broadcast vs 100ms polling."""
    runner, store = _fresh_runner()
    from repro.core import Int, ToContext, WorkChain

    Noop = _NoopChain.get()
    lat_event = []

    class Waiter(WorkChain):
        @classmethod
        def define(cls, spec):
            super().define(spec)
            spec.outline(cls.launch, cls.resume)

        def launch(self):
            self.ctx.t0 = time.perf_counter()
            return ToContext(child=self.submit(Noop, n=Int(1)))

        def resume(self):
            lat_event.append(time.perf_counter() - self.ctx.t0)

    async def main():
        for _ in range(n):
            h = runner.submit(Waiter, {})
            await h.process.wait_done()

    runner.loop.run_until_complete(main())
    mean_event = sum(lat_event) / len(lat_event)
    poll_floor = 0.100 / 2       # expected latency of a 100ms poller
    return {"name": "event_vs_poll_latency",
            "us_per_call": mean_event * 1e6,
            "derived": f"event={mean_event*1e3:.2f}ms vs "
                       f"100ms-poll floor={poll_floor*1e3:.0f}ms "
                       f"({poll_floor/mean_event:.0f}x)"}


def bench_bundling(n_jobs=50):
    """B5: connections opened + scheduler queries with N concurrent jobs."""
    from repro.calcjobs.scheduler import SimScheduler, SimulatedCluster
    from repro.engine.jobmanager import JobManager
    from repro.engine.transport import TransportQueue

    cluster = SimulatedCluster(queue_delay=0.0, runtime=10.0)

    async def main():
        tq = TransportQueue(safe_interval=0.0)
        tq.register_transport(cluster.make_transport("hpc"))
        mgr = JobManager(tq, SimScheduler(), "hpc", flush_interval=0.01)
        t = await tq.request_transport("hpc")
        ids = []
        for i in range(n_jobs):
            t.files[f"s{i}.job"] = b"{}"
            _, out, _ = await t.exec_command(f"sbatch s{i}.job")
            ids.append(out.rsplit(" ", 1)[-1])
        t0 = time.perf_counter()
        await asyncio.gather(*[mgr.request_job_state(j) for j in ids])
        dt = time.perf_counter() - t0
        return dt, cluster.stats["queries"], tq.stats["opens"]

    loop = asyncio.new_event_loop()
    dt, queries, opens = loop.run_until_complete(main())
    loop.close()
    return {"name": "bundled_updates",
            "us_per_call": dt / n_jobs * 1e6,
            "derived": f"{n_jobs} jobs -> {queries - n_jobs // n_jobs + 1} "
                       f"status queries, {opens} connection opens "
                       f"(unbundled would be {n_jobs})"}


def bench_fault_injection(n_jobs=4):
    """B6: wall-time completing jobs over a flaky transport vs clean."""
    from repro.calcjobs import TPUTrainJob
    from repro.calcjobs.calcjob import get_cluster
    from repro.core import Dict
    from repro.engine.transport import FlakyTransport

    cfg = {"arch": "qwen2-0.5b", "steps": 1, "batch": 1, "seq": 8}

    def run_batch(flaky: bool):
        runner, store = _fresh_runner()
        cluster = get_cluster(runner)
        host = "hpc"
        if flaky:
            t = FlakyTransport(fail_first=2, hostname=host)
            t.command_handler = cluster.handle_command
            t.files = cluster.filesystems.setdefault(host, {})
            runner.transport_queue.register_transport(t)

        async def main():
            hs = [runner.submit(TPUTrainJob, {
                "config": Dict({**cfg, "seed": i}),
                "metadata": {"computer": host}}) for i in range(n_jobs)]
            for h in hs:
                await h.process.wait_done()
            return [h.process.exit_code.status for h in hs]

        t0 = time.perf_counter()
        statuses = runner.loop.run_until_complete(main())
        return time.perf_counter() - t0, statuses

    run_batch(False)                     # warm the jit/executable caches
    t_clean, s_clean = run_batch(False)
    t_flaky, s_flaky = run_batch(True)
    assert all(s == 0 for s in s_clean + s_flaky)
    return {"name": "fault_injection_recovery",
            "us_per_call": t_flaky / n_jobs * 1e6,
            "derived": f"clean={t_clean:.2f}s flaky={t_flaky:.2f}s "
                       f"(overhead {t_flaky/t_clean:.2f}x, all finished ok)"}


def bench_checkpointing():
    """B7: tensor checkpoint MB/s + process checkpoint latency."""
    import jax.numpy as jnp
    import numpy as np

    from repro.training import checkpoint as ckpt

    state = {"params": {f"w{i}": jnp.asarray(
        np.random.default_rng(i).normal(size=(512, 512)), jnp.float32)
        for i in range(8)}}
    nbytes = 8 * 512 * 512 * 4
    with tempfile.TemporaryDirectory() as d:
        t0 = time.perf_counter()
        ckpt.save_checkpoint(d, 1, state)
        t_save = time.perf_counter() - t0
        t0 = time.perf_counter()
        ckpt.restore_checkpoint(d, target=state)
        t_load = time.perf_counter() - t0

    runner, store = _fresh_runner()
    Noop = _NoopChain.get()
    proc = Noop(inputs={}, runner=runner)
    t0 = time.perf_counter()
    for _ in range(50):
        store.save_checkpoint(proc.pk, proc.get_checkpoint())
    t_proc = (time.perf_counter() - t0) / 50
    return {"name": "checkpointing",
            "us_per_call": t_save * 1e6,
            "derived": f"save={nbytes/t_save/1e6:.0f}MB/s "
                       f"load={nbytes/t_load/1e6:.0f}MB/s "
                       f"process-ckpt={t_proc*1e3:.2f}ms"}


def bench_remote_wait_latency(n=30):
    """B8: terminal-notification latency for a REMOTE process — the waiter
    holds no local handle, so completion must travel as a broadcast
    through the broker. p50 must sit at event-delivery time (< 50 ms),
    not at the old ~2 s poll-interval floor."""
    import os
    import tempfile

    from repro.engine.broker import BrokerClient, BrokerServer
    from repro.engine.runner import Runner
    from repro.provenance.store import configure_store
    from repro.core import Int

    Noop = _NoopChain.get()

    async def main(tmpdir):
        server = BrokerServer(os.path.join(tmpdir, "broker.db"))
        host, port = await server.start()
        worker = BrokerClient(host, port)
        await worker.connect()
        waiter = BrokerClient(host, port)
        await waiter.connect()
        store = configure_store(":memory:")
        runner_w = Runner(store=store, communicator=worker)
        runner_c = Runner(store=store, communicator=waiter)

        emitted: dict[int, float] = {}

        def stamp(subject, sender, body):
            if body.get("state") in ("finished", "excepted", "killed"):
                emitted[body["pk"]] = body["ts"]

        waiter.add_broadcast_subscriber(stamp, "state_changed.*")

        lats = []
        for i in range(n):
            handle = runner_w.submit(Noop, {"n": Int(i)})
            assert handle.pk not in runner_c._processes
            await runner_c.wait(handle.pk)
            # latency: wait unblocked minus the terminal broadcast's
            # emission stamp — the pure control-plane delivery time
            lats.append(time.time() - emitted[handle.pk])
        worker.close()
        waiter.close()
        await server.stop()
        return lats

    with tempfile.TemporaryDirectory() as tmpdir:
        loop = asyncio.new_event_loop()
        try:
            lats = loop.run_until_complete(main(tmpdir))
        finally:
            loop.close()
    lats.sort()
    p50 = lats[len(lats) // 2]
    p95 = lats[int(len(lats) * 0.95)]
    assert p50 < 0.050, f"p50 wait latency {p50*1e3:.1f}ms >= 50ms"
    return {"name": "remote_wait_latency",
            "us_per_call": p50 * 1e6,
            "derived": f"p50={p50*1e3:.2f}ms p95={p95*1e3:.2f}ms over "
                       f"{n} remote waits (old poll floor was ~2000ms)"}


ALL = [
    bench_engine_throughput,
    bench_slot_scaling,
    bench_provenance_overhead,
    bench_event_vs_poll_latency,
    bench_bundling,
    bench_fault_injection,
    bench_checkpointing,
    bench_remote_wait_latency,
]
