"""Engine benchmarks mirroring the paper's claims.

One function per claim ("table"):
  B1 engine process throughput vs slots (vertical scaling, fig. 5)
  B2 daemon worker scaling (horizontal scaling, fig. 5)
  B3 provenance overhead per process (criterion (v))
  B4 event-driven wake-up vs polling latency (§III.A)
  B5 transport-queue + job-manager bundling (connection/query counts)
  B6 robustness: completion under fault injection (backoff, §II.B.4.a)
  B7 checkpoint save/restore throughput (engine + tensor level)
  B8 remote terminal-notification latency through the broker (§III.C):
     Runner.wait unblocks at event-delivery time, not a poll interval
  B9 engine saturation (CLI only: ``python benchmarks/engine_bench.py
     --b9 [--smoke]``): 100k queued / 10k live calcfunctions through a
     real daemon — throughput, p50/p99 pickup latency, broker messages
     per process, worker peak RSS
"""

from __future__ import annotations

import asyncio
import json
import sys
import tempfile
import time


def _fresh_runner(slots=200):
    from repro.engine.runner import Runner, set_default_runner
    from repro.provenance.store import configure_store

    store = configure_store(":memory:")
    runner = Runner(store=store, slots=slots)
    set_default_runner(runner)
    return runner, store


class _NoopChain:
    _cls = None

    @classmethod
    def get(cls):
        if cls._cls is None:
            from repro.core import Int, WorkChain

            class Noop(WorkChain):
                @classmethod
                def define(klass, spec):
                    super(Noop, klass).define(spec)
                    spec.input("n", valid_type=Int, default=Int(0))
                    spec.output("r", valid_type=Int)
                    spec.outline(klass.go)

                def go(self):
                    self.out("r", Int(self.inputs["n"].value + 1))

            cls._cls = Noop
        return cls._cls


def bench_engine_throughput(n_processes=200, slots=100):
    """B1: processes/second through one runner (event loop + provenance)."""
    runner, store = _fresh_runner(slots)
    Noop = _NoopChain.get()
    from repro.core import Int

    async def main():
        t0 = time.perf_counter()
        handles = [runner.submit(Noop, {"n": Int(i)})
                   for i in range(n_processes)]
        for h in handles:
            await h.process.wait_done()
        return time.perf_counter() - t0

    elapsed = runner.loop.run_until_complete(main())
    per = elapsed / n_processes
    return {"name": "engine_throughput",
            "us_per_call": per * 1e6,
            "derived": f"{n_processes/elapsed:.0f} proc/s @ {slots} slots"}


def bench_slot_scaling():
    """B1b: throughput at different slot counts (vertical axis of fig 5)."""
    rows = []
    for slots in (1, 10, 100):
        runner, _ = _fresh_runner(slots)
        Noop = _NoopChain.get()
        from repro.core import Int

        async def main():
            t0 = time.perf_counter()
            hs = [runner.submit(Noop, {"n": Int(i)}) for i in range(100)]
            for h in hs:
                await h.process.wait_done()
            return time.perf_counter() - t0

        dt = runner.loop.run_until_complete(main())
        rows.append((slots, 100 / dt))
    derived = "; ".join(f"{s} slots={r:.0f}/s" for s, r in rows)
    return {"name": "slot_scaling", "us_per_call": 1e6 / rows[-1][1],
            "derived": derived}


def bench_provenance_overhead(n=300):
    """B3: calcfunction call vs bare python call."""
    runner, store = _fresh_runner()
    from repro.core import Int, calcfunction

    def bare(a, b):
        return a + b

    @calcfunction
    def tracked(a, b):
        return a + b

    t0 = time.perf_counter()
    for i in range(n):
        bare(i, i)
    t_bare = time.perf_counter() - t0

    t0 = time.perf_counter()
    for i in range(n):
        tracked(Int(i), Int(i))
    t_tracked = (time.perf_counter() - t0) / n
    nodes = store.count_nodes()
    return {"name": "provenance_overhead",
            "us_per_call": t_tracked * 1e6,
            "derived": f"bare={t_bare/n*1e6:.1f}us; "
                       f"{nodes} nodes stored; "
                       f"overhead={t_tracked*1e6:.0f}us/process"}


def bench_event_vs_poll_latency(n=20):
    """B4: parent wake-up latency after child terminates — event-driven
    broadcast vs 100ms polling."""
    runner, store = _fresh_runner()
    from repro.core import Int, ToContext, WorkChain

    Noop = _NoopChain.get()
    lat_event = []

    class Waiter(WorkChain):
        @classmethod
        def define(cls, spec):
            super().define(spec)
            spec.outline(cls.launch, cls.resume)

        def launch(self):
            self.ctx.t0 = time.perf_counter()
            return ToContext(child=self.submit(Noop, n=Int(1)))

        def resume(self):
            lat_event.append(time.perf_counter() - self.ctx.t0)

    async def main():
        for _ in range(n):
            h = runner.submit(Waiter, {})
            await h.process.wait_done()

    runner.loop.run_until_complete(main())
    mean_event = sum(lat_event) / len(lat_event)
    poll_floor = 0.100 / 2       # expected latency of a 100ms poller
    return {"name": "event_vs_poll_latency",
            "us_per_call": mean_event * 1e6,
            "derived": f"event={mean_event*1e3:.2f}ms vs "
                       f"100ms-poll floor={poll_floor*1e3:.0f}ms "
                       f"({poll_floor/mean_event:.0f}x)"}


def bench_bundling(n_jobs=50):
    """B5: connections opened + scheduler queries with N concurrent jobs."""
    from repro.calcjobs.scheduler import SimScheduler, SimulatedCluster
    from repro.engine.jobmanager import JobManager
    from repro.engine.transport import TransportQueue

    cluster = SimulatedCluster(queue_delay=0.0, runtime=10.0)

    async def main():
        tq = TransportQueue(safe_interval=0.0)
        tq.register_transport(cluster.make_transport("hpc"))
        mgr = JobManager(tq, SimScheduler(), "hpc", flush_interval=0.01)
        t = await tq.request_transport("hpc")
        ids = []
        for i in range(n_jobs):
            t.files[f"s{i}.job"] = b"{}"
            _, out, _ = await t.exec_command(f"sbatch s{i}.job")
            ids.append(out.rsplit(" ", 1)[-1])
        t0 = time.perf_counter()
        await asyncio.gather(*[mgr.request_job_state(j) for j in ids])
        dt = time.perf_counter() - t0
        return dt, cluster.stats["queries"], tq.stats["opens"]

    loop = asyncio.new_event_loop()
    dt, queries, opens = loop.run_until_complete(main())
    loop.close()
    return {"name": "bundled_updates",
            "us_per_call": dt / n_jobs * 1e6,
            "derived": f"{n_jobs} jobs -> {queries - n_jobs // n_jobs + 1} "
                       f"status queries, {opens} connection opens "
                       f"(unbundled would be {n_jobs})"}


def bench_fault_injection(n_jobs=4):
    """B6: wall-time completing jobs over a flaky transport vs clean."""
    from repro.calcjobs import TPUTrainJob
    from repro.calcjobs.calcjob import get_cluster
    from repro.core import Dict
    from repro.engine.transport import FlakyTransport

    cfg = {"arch": "qwen2-0.5b", "steps": 1, "batch": 1, "seq": 8}

    def run_batch(flaky: bool):
        runner, store = _fresh_runner()
        cluster = get_cluster(runner)
        host = "hpc"
        if flaky:
            t = FlakyTransport(fail_first=2, hostname=host)
            t.command_handler = cluster.handle_command
            t.files = cluster.filesystems.setdefault(host, {})
            runner.transport_queue.register_transport(t)

        async def main():
            hs = [runner.submit(TPUTrainJob, {
                "config": Dict({**cfg, "seed": i}),
                "metadata": {"computer": host}}) for i in range(n_jobs)]
            for h in hs:
                await h.process.wait_done()
            return [h.process.exit_code.status for h in hs]

        t0 = time.perf_counter()
        statuses = runner.loop.run_until_complete(main())
        return time.perf_counter() - t0, statuses

    run_batch(False)                     # warm the jit/executable caches
    t_clean, s_clean = run_batch(False)
    t_flaky, s_flaky = run_batch(True)
    assert all(s == 0 for s in s_clean + s_flaky)
    return {"name": "fault_injection_recovery",
            "us_per_call": t_flaky / n_jobs * 1e6,
            "derived": f"clean={t_clean:.2f}s flaky={t_flaky:.2f}s "
                       f"(overhead {t_flaky/t_clean:.2f}x, all finished ok)"}


def bench_checkpointing():
    """B7: tensor checkpoint MB/s + process checkpoint latency."""
    import jax.numpy as jnp
    import numpy as np

    from repro.training import checkpoint as ckpt

    state = {"params": {f"w{i}": jnp.asarray(
        np.random.default_rng(i).normal(size=(512, 512)), jnp.float32)
        for i in range(8)}}
    nbytes = 8 * 512 * 512 * 4
    with tempfile.TemporaryDirectory() as d:
        t0 = time.perf_counter()
        ckpt.save_checkpoint(d, 1, state)
        t_save = time.perf_counter() - t0
        t0 = time.perf_counter()
        ckpt.restore_checkpoint(d, target=state)
        t_load = time.perf_counter() - t0

    runner, store = _fresh_runner()
    Noop = _NoopChain.get()
    proc = Noop(inputs={}, runner=runner)
    t0 = time.perf_counter()
    for _ in range(50):
        store.save_checkpoint(proc.pk, proc.get_checkpoint())
    t_proc = (time.perf_counter() - t0) / 50
    return {"name": "checkpointing",
            "us_per_call": t_save * 1e6,
            "derived": f"save={nbytes/t_save/1e6:.0f}MB/s "
                       f"load={nbytes/t_load/1e6:.0f}MB/s "
                       f"process-ckpt={t_proc*1e3:.2f}ms"}


def bench_remote_wait_latency(n=30):
    """B8: terminal-notification latency for a REMOTE process — the waiter
    holds no local handle, so completion must travel as a broadcast
    through the broker. p50 must sit at event-delivery time (< 50 ms),
    not at the old ~2 s poll-interval floor."""
    import os
    import tempfile

    from repro.engine.broker import BrokerClient, BrokerServer
    from repro.engine.runner import Runner
    from repro.provenance.store import configure_store
    from repro.core import Int

    Noop = _NoopChain.get()

    async def main(tmpdir):
        server = BrokerServer(os.path.join(tmpdir, "broker.db"))
        host, port = await server.start()
        worker = BrokerClient(host, port)
        await worker.connect()
        waiter = BrokerClient(host, port)
        await waiter.connect()
        store = configure_store(":memory:")
        runner_w = Runner(store=store, communicator=worker)
        runner_c = Runner(store=store, communicator=waiter)

        emitted: dict[int, float] = {}

        def stamp(subject, sender, body):
            if body.get("state") in ("finished", "excepted", "killed"):
                emitted[body["pk"]] = body["ts"]

        waiter.add_broadcast_subscriber(stamp, "state_changed.*")

        lats = []
        for i in range(n):
            handle = runner_w.submit(Noop, {"n": Int(i)})
            assert handle.pk not in runner_c._processes
            await runner_c.wait(handle.pk)
            # latency: wait unblocked minus the terminal broadcast's
            # emission stamp — the pure control-plane delivery time
            lats.append(time.time() - emitted[handle.pk])
        worker.close()
        waiter.close()
        await server.stop()
        return lats

    with tempfile.TemporaryDirectory() as tmpdir:
        loop = asyncio.new_event_loop()
        try:
            lats = loop.run_until_complete(main(tmpdir))
        finally:
            loop.close()
    lats.sort()
    p50 = lats[len(lats) // 2]
    p95 = lats[int(len(lats) * 0.95)]
    assert p50 < 0.050, f"p50 wait latency {p50*1e3:.1f}ms >= 50ms"
    return {"name": "remote_wait_latency",
            "us_per_call": p50 * 1e6,
            "derived": f"p50={p50*1e3:.2f}ms p95={p95*1e3:.2f}ms over "
                       f"{n} remote waits (old poll floor was ~2000ms)"}


# ---------------------------------------------------------------------------
# B9: engine saturation — 100k queued / 10k live through a real daemon
# ---------------------------------------------------------------------------

def _hist_quantile(hist: dict, q: float) -> float:
    """Linear-interpolated quantile from a fixed-bucket histogram
    snapshot (``{"buckets": bounds, "counts": [... , overflow]}``)."""
    bounds = list(hist.get("buckets", []))
    counts = list(hist.get("counts", []))
    total = hist.get("count") or sum(counts)
    if not total or not bounds:
        return 0.0
    target = q * total
    acc = 0.0
    lo = 0.0
    for i, c in enumerate(counts):
        hi = bounds[i] if i < len(bounds) else bounds[-1] * 2
        if c and acc + c >= target:
            return lo + (target - acc) / c * (hi - lo)
        acc += c
        lo = hi
    return bounds[-1] * 2


def _pid_rss_kb(pid: int) -> int:
    try:
        with open(f"/proc/{pid}/status") as fh:
            for line in fh:
                if line.startswith("VmRSS:"):
                    return int(line.split()[1])
    except (OSError, ValueError, IndexError):
        pass
    return 0


def bench_saturation(n_total=100_000, n_live=10_000, workers=4,
                     ramp_budget=60.0, poll=0.5):
    """B9: saturate a real daemon. ``n_live`` HoldCalc processes are
    pinned live (all slots held) while the remaining ``n_total - n_live``
    NoopCalcs pile up as a ready backlog behind them; when the hold
    deadline passes the backlog drains. Records drain throughput, p50/p99
    ``daemon.pickup_seconds`` (merged across workers), broker messages
    per process vs the pre-batching protocol, and worker peak RSS."""
    import math
    import random

    try:
        from benchmarks import bench_procs
    except ImportError:  # run as a script: benchmarks/ is sys.path[0]
        import bench_procs

    from repro.core import Float
    from repro.engine.broker import SyncBrokerClient
    from repro.engine.daemon import PROCESS_QUEUE, Daemon
    from repro.engine.runner import TERMINAL, Runner
    from repro.observability import metrics as _metrics
    from repro.provenance.store import SUMMARY_COLUMNS, configure_store

    n_backlog = n_total - n_live
    slots = max(1, math.ceil(n_live / workers))
    tmpdir = tempfile.mkdtemp(prefix="b9-")
    # lax heartbeat: 10k simultaneous resumes starve worker heartbeat
    # tasks for seconds; the default 1s window would requeue live work
    daemon = Daemon(tmpdir, workers=workers, slots=slots, heartbeat=10.0)
    daemon.start()
    store = configure_store(daemon.store_path)
    local = Runner(store=store)
    ctl = daemon.controller()
    stats_client = SyncBrokerClient(daemon.host, daemon.port)

    def create(cls, inputs_fn, k):
        pks, batch = [], 500
        for i in range(0, k, batch):
            with store.transaction():
                for _ in range(min(batch, k - i)):
                    pks.append(cls(inputs=inputs_fn(), runner=local).pk)
        return pks

    def live_count():
        return sum(int(w.get("resident", 0)) for w in ctl.workers())

    def rss_kb():
        return max((_pid_rss_kb(p) for p in daemon.worker_pids()),
                   default=0)

    def queue_depth():
        q = stats_client.broker_stats(timeout=30.0).get(
            "queues", {}).get(PROCESS_QUEUE, {})
        return sum(q.values())

    try:
        # -- create the backlog first (no deadline dependency), using a
        #    pilot slice to estimate the node-creation rate
        t0 = time.perf_counter()
        backlog_pks = create(bench_procs.NoopCalc, dict, min(200, n_backlog))
        create_rate = len(backlog_pks) / (time.perf_counter() - t0)
        t0 = time.perf_counter()
        backlog_pks += create(bench_procs.NoopCalc, dict,
                              n_backlog - len(backlog_pks))
        t_create = time.perf_counter() - t0

        # -- phase 1: pin n_live processes live until an absolute deadline
        #    sized to cover creation + submission + worker ramp
        until = time.time() + (n_live / create_rate) * 1.6 + ramp_budget
        hold_pks = []
        t_hold0 = time.time()
        batch = 500
        for i in range(0, n_live, batch):
            chunk = create(bench_procs.HoldCalc,
                           lambda: {"until": Float(until)},
                           min(batch, n_live - i))
            hold_pks.extend(chunk)
            daemon.send_tasks(chunk)        # overlap ramp with creation

        target = int(n_live * 0.95)
        peak_live, peak_rss, ramp_seconds = 0, 0, None
        while time.time() < until - 1.0:
            live = live_count()
            peak_live = max(peak_live, live)
            peak_rss = max(peak_rss, rss_kb())
            if live >= target:
                ramp_seconds = time.time() - t_hold0
                break
            time.sleep(poll)
        rss_at_live = rss_kb()

        # -- phase 2: queue the backlog behind the live block
        t0 = time.perf_counter()
        daemon.send_tasks(backlog_pks)
        submit_rate = n_backlog / (time.perf_counter() - t0)
        bs = stats_client.broker_stats(timeout=30.0)
        sat_q = bs.get("queues", {}).get(PROCESS_QUEUE, {})
        saturation = {"live": live_count(),
                      "ready": sat_q.get("ready", 0),
                      "inflight": sat_q.get("inflight", 0),
                      "clients": bs.get("clients", 0)}

        # -- drain: holds expire at the deadline, then the backlog flows
        while True:
            depth = queue_depth()
            peak_rss = max(peak_rss, rss_kb())
            if depth == 0:
                break
            time.sleep(poll)
        t_empty = time.time()
        drain_seconds = max(t_empty - max(until, t_hold0), 1e-9)
        rss_end = rss_kb()

        # -- no task lost: every submitted pk must be terminal in the store
        sample = ([hold_pks[0], hold_pks[-1], backlog_pks[0],
                   backlog_pks[-1]]
                  + random.sample(hold_pks + backlog_pks,
                                  min(200, n_total)))
        for pk in sample:
            node = store.get_node(pk, columns=SUMMARY_COLUMNS)
            assert node and node.get("process_state") in TERMINAL, \
                f"process {pk} not terminal after drain: {node}"

        # -- collect: merged worker metrics + broker protocol counters
        ws = ctl.workers()
        merged = _metrics.merge_snapshots(
            [w.get("metrics", {}) for w in ws])
        hist = merged.get("histograms", {}).get("daemon.pickup_seconds",
                                                {})
        p50 = _hist_quantile(hist, 0.50)
        p99 = _hist_quantile(hist, 0.99)
        bs = stats_client.broker_stats(timeout=30.0)
        payload_msgs = (bs["messages_in"] + bs["messages_out"]
                        - 2 * bs.get("heartbeats", 0))
        per_proc = payload_msgs / n_total
        # analytic per-process message count of the pre-batching protocol:
        # 1 task frame (own socket) + 2 rpc (un)register + ~3 state
        # broadcasts + 1 ack in; 1 delivery + 3 broadcasts fanned to EVERY
        # connected client out (no subject pushdown)
        n_clients = max(saturation["clients"], workers + 1)
        baseline_per_proc = 8.0 + 3.0 * n_clients
        return {
            "name": "saturation",
            "config": {"n_total": n_total, "n_live": n_live,
                       "workers": workers, "slots": slots},
            "live": {"target": target, "peak_live": peak_live,
                     "ramp_seconds": ramp_seconds,
                     "reached": ramp_seconds is not None},
            "saturation_point": saturation,
            "throughput": {
                "create_per_s": round(
                    n_backlog / t_create if t_create else create_rate, 1),
                "submit_ack_per_s": round(submit_rate, 1),
                "drain_proc_per_s": round(n_total / drain_seconds, 1),
                "drain_seconds": round(drain_seconds, 2)},
            "pickup_seconds": {
                "p50": round(p50, 3), "p99": round(p99, 3),
                "mean": round(hist.get("sum", 0.0)
                              / max(1, hist.get("count", 0)), 3),
                "count": hist.get("count", 0)},
            "broker": {
                "messages_per_process": round(per_proc, 2),
                "baseline_messages_per_process": baseline_per_proc,
                "reduction_x": round(baseline_per_proc / per_proc, 2)
                if per_proc else None,
                "messages_in": bs["messages_in"],
                "messages_out": bs["messages_out"],
                "tasks_enqueued": bs.get("tasks_enqueued"),
                "tasks_delivered": bs.get("tasks_delivered"),
                "event_log_size": bs.get("event_log_size"),
                "events_compacted": bs.get("events_compacted"),
                # lease bookkeeping must stay off the hot path: grants ride
                # the existing delivery write, zero expiries when healthy
                "leases_granted": bs.get("leases_granted"),
                "leases_expired": bs.get("leases_expired"),
                "stale_claims": bs.get("stale_claims")},
            "rss_kb": {"at_live": rss_at_live, "peak": peak_rss,
                       "end": rss_end},
        }
    finally:
        stats_client.close()
        ctl.close()
        daemon.stop()


def _b9_assert(res: dict, smoke: bool) -> None:
    """Acceptance bars (relaxed for the CI smoke size)."""
    live = res["live"]
    assert live["reached"], (
        f"never reached {live['target']} live: peak={live['peak_live']}")
    pk = res["pickup_seconds"]
    assert pk["p99"] <= max(5 * pk["p50"], 2.0), (
        f"p99 pickup {pk['p99']}s exceeds 5x p50 {pk['p50']}s")
    floor = 3.0 if smoke else 5.0
    red = res["broker"]["reduction_x"]
    assert red and red >= floor, (
        f"broker messages/process only {red}x below baseline (< {floor}x)")
    slots_kb = res["config"]["slots"] * 1024   # ~1 MB per resident process
    assert res["rss_kb"]["peak"] <= 300_000 + slots_kb, (
        f"worker RSS {res['rss_kb']['peak']}kB not bounded by slot count")


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        description="Engine benchmarks. B1-B8 run via benchmarks/run.py; "
                    "this entry point drives B9 (engine saturation).")
    ap.add_argument("--b9", action="store_true",
                    help="run the saturation bench (requires a daemon-"
                         "capable machine)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI size: 2k queued / 500 live / 2 workers")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the result document to PATH")
    args = ap.parse_args(argv)
    if not args.b9:
        ap.error("nothing to do: pass --b9 (B1-B8 run via "
                 "benchmarks/run.py)")
    if args.smoke:
        res = bench_saturation(n_total=2_000, n_live=500, workers=2,
                               ramp_budget=15.0, poll=0.25)
    else:
        res = bench_saturation()
    _b9_assert(res, smoke=args.smoke)
    print(json.dumps(res, indent=1, sort_keys=True))
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(res, fh, indent=1, sort_keys=True)
            fh.write("\n")
    return 0


ALL = [
    bench_engine_throughput,
    bench_slot_scaling,
    bench_provenance_overhead,
    bench_event_vs_poll_latency,
    bench_bundling,
    bench_fault_injection,
    bench_checkpointing,
    bench_remote_wait_latency,
]


if __name__ == "__main__":
    sys.exit(main())
