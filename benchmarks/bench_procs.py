"""Process classes for the saturation bench (B9).

They live in an importable module — NOT in the bench script — because
daemon workers recreate processes from their checkpoints by importing
``module:qualname``; classes defined under ``__main__`` cannot cross the
spawn boundary.
"""

from __future__ import annotations

import asyncio
import time

from repro.core import Float
from repro.core.process import Process
from repro.provenance.store import NodeType


class NoopCalc(Process):
    """The shortest possible calcfunction-shaped process: all of its cost
    is engine + control-plane overhead, which is what B9 measures."""

    NODE_TYPE = NodeType.CALC_FUNCTION
    CACHEABLE = False

    async def run(self):
        pass


class HoldCalc(Process):
    """Stays live (slot held, control endpoint owned) until an absolute
    wall-clock deadline — how B9 pins 10k processes live at once without
    the finish times stampeding."""

    NODE_TYPE = NodeType.CALC_FUNCTION
    CACHEABLE = False

    @classmethod
    def define(cls, spec):
        super().define(spec)
        spec.input("until", valid_type=Float)

    async def run(self):
        delay = self.inputs["until"].value - time.time()
        if delay > 0:
            await self.interruptible(asyncio.sleep(delay))
