"""Cache-hit fast path vs recomputation on a high-throughput workload.

Runs ~500 calculation processes twice against one provenance store:

* **cold** — empty cache, every process executes its body (a deterministic
  CPU-bound kernel, ~tens of ms each);
* **warm** — the same 500 submissions with caching enabled: every one
  resolves to a finished-ok node from the cold pass, clones its outputs
  and terminates without executing.

Reports both throughputs and the speedup; the acceptance bar is warm >=
10x cold. Also verifies that a warm node carries `cached_from` metadata
pointing at the original finished-ok node.

    PYTHONPATH=src python -m benchmarks.cache_bench --processes 500
"""

from __future__ import annotations

import argparse
import hashlib
import json
import sys
import time

sys.path.insert(0, "src")

import numpy as np

from repro.caching import disable_caching, enable_caching
from repro.core import Int, Process, ProcessSpec
from repro.engine.runner import Runner, set_default_runner
from repro.provenance.store import NodeType, configure_store


class HashGrind(Process):
    """A deterministic, CPU-bound 'calculation': iterated sha256 over a
    seed-derived buffer (the stand-in for a real simulation kernel)."""

    NODE_TYPE = NodeType.CALC_FUNCTION

    @classmethod
    def define(cls, spec: ProcessSpec) -> None:
        super().define(spec)
        spec.input("seed", valid_type=Int)
        spec.input("rounds", valid_type=Int, default=Int(1200))
        spec.output("digest", valid_type=Int)

    async def run(self):
        buf = np.random.default_rng(self.inputs["seed"].value) \
            .bytes(1 << 14)
        for _ in range(self.inputs["rounds"].value):
            buf = hashlib.sha256(buf).digest() + buf[:1 << 14]
        self.out("digest",
                 Int(int.from_bytes(hashlib.sha256(buf).digest()[:6], "big")))


def run_pass(runner: Runner, n: int, rounds: int) -> float:
    async def main() -> float:
        t0 = time.perf_counter()
        handles = [runner.submit(HashGrind, {"seed": Int(i),
                                             "rounds": Int(rounds)})
                   for i in range(n)]
        for h in handles:
            await h.process.wait_done()
        assert all(h.process.is_finished_ok for h in handles)
        return time.perf_counter() - t0

    return runner.loop.run_until_complete(main())


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--processes", type=int, default=500)
    ap.add_argument("--rounds", type=int, default=1200,
                    help="sha256 rounds per process (cold-pass work)")
    ap.add_argument("--slots", type=int, default=100)
    args = ap.parse_args()

    store = configure_store(":memory:")
    runner = Runner(store=store, slots=args.slots)
    set_default_runner(runner)

    with disable_caching():
        t_cold = run_pass(runner, args.processes, args.rounds)
    cold_tp = args.processes / t_cold

    with enable_caching(HashGrind):
        t_warm = run_pass(runner, args.processes, args.rounds)
    warm_tp = args.processes / t_warm

    # every warm node must be a clone of a cold finished-ok node
    rows = store._conn().execute(
        "SELECT pk, attributes FROM nodes WHERE process_type='HashGrind'"
        " ORDER BY pk").fetchall()
    warm_rows = rows[args.processes:]
    hits = 0
    for r in warm_rows:
        attrs = json.loads(r["attributes"] or "{}")
        src_pk = attrs.get("cached_from_pk")
        if src_pk is None:
            continue
        src = store.get_node(src_pk)
        assert src["process_state"] == "finished" and \
            src["exit_status"] == 0, f"bad cache source for {r['pk']}"
        assert attrs["cached_from"] == src["uuid"]
        hits += 1
    speedup = warm_tp / cold_tp

    print(f"processes:        {args.processes}")
    print(f"cold:  {t_cold:6.2f}s  ({cold_tp:8.1f} proc/s)")
    print(f"warm:  {t_warm:6.2f}s  ({warm_tp:8.1f} proc/s)")
    print(f"cache hits:       {hits}/{len(warm_rows)} "
          f"(each with cached_from -> finished-ok source)")
    print(f"speedup:          {speedup:.1f}x "
          f"({'PASS' if speedup >= 10 else 'FAIL'}: bar is 10x)")
    if hits != len(warm_rows) or speedup < 10:
        sys.exit(1)


if __name__ == "__main__":
    main()
