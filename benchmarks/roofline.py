"""Roofline analysis (deliverable g): derive the three terms per
(arch x shape x mesh) from the compiled dry-run artifacts.

    compute    = FLOPs_per_chip / 197e12           (bf16 peak, TPU v5e)
    memory     = HBM_bytes_per_chip / 819e9
    collective = wire_bytes_per_chip / 50e9         (per-link, conservative)

Sources:
  * per-layer slope extrapolation over the unrolled L=2/L=4 cells
    (XLA counts scan bodies once — see analytic.py docstring);
  * closed-form corrections for in-layer scans (chunked attention,
    RG-LRU / mLSTM / sLSTM recurrences);
  * collective wire bytes parsed from the compiled HLO with a ring model
    (launch/dryrun.py::collective_stats).

Outputs a markdown table + per-cell dicts consumed by EXPERIMENTS.md.
"""

from __future__ import annotations

import glob
import json
import os
from typing import Any

from repro.configs import ARCH_IDS, get_config
from repro.launch.mesh import HW
from repro.models.registry import SHAPES

from benchmarks import analytic

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")

SCANNED_FAMILIES = ("dense", "moe", "vlm", "audio")

_PARAM_CACHE: dict[str, int] = {}


def _active_params(cfg) -> int:
    """MoE experts contribute k/E of their parameters per token."""
    import math

    import jax

    if cfg.name in _PARAM_CACHE:
        return _PARAM_CACHE[cfg.name]
    from repro.models.registry import build as build_model

    bundle = build_model(cfg)
    total = 0
    flat = jax.tree_util.tree_flatten_with_path(bundle.param_shapes())[0]
    for path, leaf in flat:
        n = math.prod(leaf.shape)
        keys = "/".join(str(p) for p in path)
        if cfg.family == "moe" and "moe" in keys and any(
                w in keys for w in ("w_gate", "w_up", "w_down")):
            n = n * cfg.num_experts_per_tok // cfg.num_experts
        total += n
    _PARAM_CACHE[cfg.name] = total
    return total


def _load(arch, shape, mesh, variant):
    path = os.path.join(DRYRUN_DIR, f"{arch}__{shape}__{mesh}__{variant}.json")
    if not os.path.exists(path):
        return None
    with open(path) as fh:
        return json.load(fh)


def _slope_extrapolate(arch, shape, mesh, variant, key_path, full_layers):
    """intercept + slope*L from the L2/L4 cells; key_path digs into JSON."""
    l2 = _load(arch, shape, mesh, f"{variant}_L2")
    l4 = _load(arch, shape, mesh, f"{variant}_L4")
    if not l2 or not l4 or "error" in l2 or "error" in l4:
        return None

    def dig(d):
        for k in key_path:
            d = d.get(k, {})
        return float(d) if isinstance(d, (int, float)) else None

    f2, f4 = dig(l2), dig(l4)
    if f2 is None or f4 is None:
        return None
    slope = (f4 - f2) / 2.0
    intercept = f2 - 2.0 * slope
    return intercept + slope * full_layers


def analyse_cell(arch: str, shape: str, mesh: str = "single",
                 variant: str = "baseline",
                 pallas_projection: bool = False) -> dict[str, Any] | None:
    """pallas_projection=True models swapping the XLA chunked attention for
    the fused Pallas flash kernel (kernels/flash_attention, validated in
    interpret mode): executed attention flops drop to the mask-aware useful
    count (block skipping) and the online-softmax carry traffic disappears
    (it lives in VMEM), leaving only q/k/v/o streaming bytes. Collective
    bytes are additionally modelled at native bf16 (the fp32 all-reduce
    promotion observed in the dry-run is a CPU-backend lowering artifact)."""
    main = _load(arch, shape, mesh, variant)
    if main is None:
        return None
    if main.get("skipped"):
        return {"arch": arch, "shape": shape, "mesh": mesh,
                "variant": variant, "skipped": True,
                "reason": main.get("reason", "")}
    if "error" in main:
        return {"arch": arch, "shape": shape, "mesh": mesh,
                "variant": variant, "error": main["error"][-300:]}

    cfg = get_config(arch)
    vd = main.get("variant_detail", {})
    cfg = cfg.replace(remat_policy=vd.get("remat_policy",
                                          "nothing_saveable"))
    cell = SHAPES[shape]
    chips = main["n_devices"]
    L = cfg.num_layers

    scanned = cfg.family in SCANNED_FAMILIES

    # --- per-device HLO flops / bytes -------------------------------------
    if scanned:
        flops = _slope_extrapolate(arch, shape, mesh, variant,
                                   ("cost_analysis", "flops"), L)
        bytes_ = _slope_extrapolate(arch, shape, mesh, variant,
                                    ("cost_analysis", "bytes accessed"), L)
        wire = _slope_extrapolate(arch, shape, mesh, variant,
                                  ("collectives", "total_wire_bytes"), L)
    else:
        flops = main["cost_analysis"].get("flops")
        bytes_ = main["cost_analysis"].get("bytes accessed")
        wire = main["collectives"]["total_wire_bytes"]
    if flops is None:
        flops = main["cost_analysis"].get("flops", 0.0)
    if bytes_ is None:
        bytes_ = main["cost_analysis"].get("bytes accessed", 0.0)
    if wire is None:
        wire = main["collectives"]["total_wire_bytes"]
    # slope extrapolation can go slightly negative on tiny intercepts
    flops = max(flops, 0.0)
    bytes_ = max(bytes_, 0.0)
    wire = max(wire, 0.0)

    # --- in-layer scan corrections (global -> per-device) -----------------
    if pallas_projection:
        # flash kernel: skip-masked blocks (useful flops only), carry in
        # VMEM (streaming bytes only), bf16 collectives on real TPU
        exec_fl = analytic.attn_executed_flops(cfg, cell)
        useful_fl = analytic.attn_useful_flops(cfg, cell)
        blk = min(cfg.attn_kv_block, cell.seq_len)
        nblk = max(1, cell.seq_len // max(1, blk))
        flops += (useful_fl - exec_fl / nblk) / chips \
            if cfg.family in SCANNED_FAMILIES or cfg.family == "hybrid" \
            else useful_fl / chips
        flops = max(flops, 0.0)
        stream = analytic.attn_executed_bytes(
            cfg.replace(attn_kv_block=cell.seq_len), cell)  # nblk=1: no carry
        bytes_ += stream / chips
        wire *= 0.5
    else:
        flops += analytic.inner_scan_flop_correction(cfg, cell) / chips
        bytes_ += analytic.attn_executed_bytes(cfg, cell) / chips

    # --- the three terms ----------------------------------------------------
    t_compute = flops / HW["peak_bf16_flops"]
    t_memory = bytes_ / HW["hbm_bandwidth"]
    t_coll = wire / HW["ici_link_bandwidth"]
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)

    # recompute the active-param count locally (early dry-run JSONs carried
    # an int32-overflowed value)
    params_active = _active_params(cfg)

    mf = analytic.model_flops(cfg, cell, params_active)
    mf_dev = mf / chips
    bound = max(terms.values())
    # MFU this program could reach if perfectly overlapped
    mfu_bound = (mf_dev / HW["peak_bf16_flops"]) / bound if bound > 0 else 0.0

    mem = main.get("memory_analysis", {})
    hbm_per_dev = (mem.get("argument_size_in_bytes", 0) +
                   mem.get("temp_size_in_bytes", 0))

    return {
        "arch": arch, "shape": shape, "mesh": mesh, "variant": variant,
        "skipped": False,
        "chips": chips,
        "flops_per_dev": flops,
        "bytes_per_dev": bytes_,
        "wire_per_dev": wire,
        "dcn_wire": main["collectives"].get("dcn_wire_bytes", 0.0),
        "t_compute": t_compute,
        "t_memory": t_memory,
        "t_collective": t_coll,
        "dominant": dominant,
        "model_flops": mf,
        "model_hlo_ratio": (mf_dev / flops) if flops else 0.0,
        "mfu_bound": mfu_bound,
        "hbm_bytes_per_dev": hbm_per_dev,
        "fits_hbm": hbm_per_dev <= HW["hbm_bytes"],
        "compile_s": main.get("compile_s"),
        "counts": main["collectives"]["counts"],
    }


RECOMMENDATION = {
    "compute": "compute-bound: raise MFU via fused attention kernels and "
               "lighter remat",
    "memory": "HBM-bound: cut activation/carry traffic (fused flash kernel "
              "keeps the online-softmax carry in VMEM), quantize the KV "
              "cache, stream weights once",
    "collective": "collective-bound: shard to kill the per-layer "
                  "activation all-reduces (FSDP + better batch split), "
                  "compress gradients, overlap via latency-hiding scheduler",
}


def table(variant: str = "baseline", mesh: str = "single",
          archs=None) -> str:
    rows = []
    archs = archs or [a for a in ARCH_IDS if a != "aiida-demo-110m"]
    for arch in archs:
        for shape in SHAPES:
            r = analyse_cell(arch, shape, mesh, variant)
            if r is None:
                continue
            rows.append(r)
    lines = [
        f"### Roofline — variant `{variant}`, mesh `{mesh}` "
        f"(terms in ms/step per chip)",
        "",
        "| arch | shape | compute | memory | collective | dominant | "
        "MFU-bound | model/HLO | HBM GB | fits |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r.get("skipped"):
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skip | — | — | — | — |")
            continue
        if "error" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | ERR | | | | | | | |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} "
            f"| {r['t_compute']*1e3:.1f} "
            f"| {r['t_memory']*1e3:.1f} "
            f"| {r['t_collective']*1e3:.1f} "
            f"| **{r['dominant']}** "
            f"| {r['mfu_bound']*100:.0f}% "
            f"| {r['model_hlo_ratio']:.2f} "
            f"| {r['hbm_bytes_per_dev']/2**30:.1f} "
            f"| {'y' if r['fits_hbm'] else 'NO'} |")
    return "\n".join(lines)


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--arch", default=None)
    args = ap.parse_args()
    archs = args.arch.split(",") if args.arch else None
    print(table(args.variant, args.mesh, archs))


if __name__ == "__main__":
    main()
