"""Archive round-trip cost and the imported-cache-hit speedup.

Measures the full cross-profile sharing loop on one machine:

* **compute** — N deterministic CPU-bound calculations in profile A;
* **export** — closure traversal + zip serialization of the finished
  graph (reports nodes/s and archive MB);
* **import** — merge into a fresh profile B with pk remapping;
* **warm relaunch** — the same N submissions in B with caching enabled:
  every process must short-circuit against an imported node.

The acceptance bar: every relaunched process is a cache hit whose
`cached_from` resolves to an imported finished-ok node, and the warm
relaunch beats recomputation by >= 5x.

    PYTHONPATH=src python -m benchmarks.archive_bench --processes 200
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
import tempfile
import time

sys.path.insert(0, "src")

import numpy as np

from repro.caching import disable_caching, enable_caching
from repro.core import Int, Process, ProcessSpec
from repro.engine.runner import Runner, set_default_runner
from repro.provenance import (
    NodeType, ProvenanceStore, configure_store, export_archive,
    import_archive,
)


class HashGrind(Process):
    """Iterated sha256 over a seed-derived buffer (same kernel as
    cache_bench, so numbers are comparable)."""

    NODE_TYPE = NodeType.CALC_FUNCTION

    @classmethod
    def define(cls, spec: ProcessSpec) -> None:
        super().define(spec)
        spec.input("seed", valid_type=Int)
        spec.input("rounds", valid_type=Int, default=Int(800))
        spec.output("digest", valid_type=Int)

    async def run(self):
        buf = np.random.default_rng(self.inputs["seed"].value).bytes(1 << 14)
        for _ in range(self.inputs["rounds"].value):
            buf = hashlib.sha256(buf).digest() + buf[:1 << 14]
        self.out("digest",
                 Int(int.from_bytes(hashlib.sha256(buf).digest()[:6], "big")))


def run_pass(runner: Runner, n: int, rounds: int) -> float:
    async def main() -> float:
        t0 = time.perf_counter()
        handles = [runner.submit(HashGrind, {"seed": Int(i),
                                             "rounds": Int(rounds)})
                   for i in range(n)]
        for h in handles:
            await h.process.wait_done()
        assert all(h.process.is_finished_ok for h in handles)
        return time.perf_counter() - t0

    return runner.loop.run_until_complete(main())


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--processes", type=int, default=200)
    ap.add_argument("--rounds", type=int, default=800)
    ap.add_argument("--slots", type=int, default=100)
    args = ap.parse_args()
    workdir = tempfile.mkdtemp(prefix="archive_bench_")
    archive = os.path.join(workdir, "results.zip")

    # -- profile A: compute + export ---------------------------------------
    # stores are in-memory (cache_bench methodology: measure engine and
    # archive cost, not sqlite fsync); the archive itself is a real file
    store_a = configure_store(":memory:")
    runner_a = Runner(store=store_a, slots=args.slots)
    set_default_runner(runner_a)
    with disable_caching():
        t_compute = run_pass(runner_a, args.processes, args.rounds)

    t0 = time.perf_counter()
    manifest = export_archive(store_a, archive)
    t_export = time.perf_counter() - t0
    size_mb = os.path.getsize(archive) / 1e6

    # -- profile B: import + warm relaunch ---------------------------------
    store_b = configure_store(":memory:")
    runner_b = Runner(store=store_b, slots=args.slots)
    set_default_runner(runner_b)
    t0 = time.perf_counter()
    result = import_archive(store_b, archive)
    t_import = time.perf_counter() - t0
    assert result.nodes_imported == manifest["nodes"], "fresh store: all new"

    with enable_caching(HashGrind):
        t_warm = run_pass(runner_b, args.processes, args.rounds)

    # every warm node must clone an *imported* finished-ok node
    rows = store_b._conn().execute(
        "SELECT pk, attributes FROM nodes WHERE process_type='HashGrind'"
        " ORDER BY pk").fetchall()
    warm_rows = rows[args.processes:]
    hits = 0
    for r in warm_rows:
        attrs = json.loads(r["attributes"] or "{}")
        src_pk = attrs.get("cached_from_pk")
        if src_pk is None:
            continue
        src = store_b.get_node(src_pk)
        assert src["process_state"] == "finished" and \
            src["exit_status"] == 0, f"bad cache source for {r['pk']}"
        hits += 1
    speedup = t_compute / t_warm

    n = manifest["nodes"]
    print(f"processes:        {args.processes}  ({n} graph nodes)")
    print(f"compute (A):      {t_compute:6.2f}s")
    print(f"export:           {t_export:6.2f}s  "
          f"({n / t_export:8.0f} nodes/s, {size_mb:.1f} MB)")
    print(f"import (B):       {t_import:6.2f}s  ({n / t_import:8.0f} nodes/s)")
    print(f"warm relaunch:    {t_warm:6.2f}s")
    print(f"imported hits:    {hits}/{len(warm_rows)}")
    print(f"speedup:          {speedup:.1f}x "
          f"({'PASS' if speedup >= 5 else 'FAIL'}: bar is 5x)")
    if hits != len(warm_rows) or speedup < 5:
        sys.exit(1)


if __name__ == "__main__":
    main()
