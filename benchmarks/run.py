"""Benchmark harness: one function per paper claim, printing
``name,us_per_call,derived`` CSV rows, plus the roofline summary of the
three hillclimbed cells (full tables live in EXPERIMENTS.md).

    PYTHONPATH=src python -m benchmarks.run
"""

import sys
import traceback


def main() -> None:
    sys.path.insert(0, "src")
    from benchmarks import engine_bench

    print("name,us_per_call,derived")
    for fn in engine_bench.ALL:
        try:
            row = fn()
            print(f"{row['name']},{row['us_per_call']:.1f},"
                  f"\"{row['derived']}\"", flush=True)
        except Exception:  # noqa: BLE001
            print(f"{fn.__name__},ERROR,\"{traceback.format_exc()[-200:]}\"",
                  flush=True)

    # roofline summaries for the hillclimbed cells (read from dry-run JSONs)
    try:
        from benchmarks import roofline
        cells = [
            ("deepseek-67b", "train_4k", ["baseline", "zero3",
                                          "zero3_full_remat", "zero3_ce"]),
            ("grok-1-314b", "train_4k", ["baseline", "zero3", "zero3_af",
                                         "tp_cf1"]),
            ("deepseek-67b", "decode_32k", ["baseline", "serve_opt",
                                            "serve_opt_2d", "serve_act"]),
        ]
        for arch, shape, variants in cells:
            for var in variants:
                r = roofline.analyse_cell(arch, shape, "single", var)
                if r is None or r.get("skipped") or "error" in r:
                    continue
                derived = (f"compute={r['t_compute']*1e3:.0f}ms "
                           f"memory={r['t_memory']*1e3:.0f}ms "
                           f"collective={r['t_collective']*1e3:.0f}ms "
                           f"dominant={r['dominant']} "
                           f"mfu_bound={r['mfu_bound']*100:.0f}%")
                print(f"roofline[{arch}|{shape}|{var}],"
                      f"{max(r['t_compute'], r['t_memory'],
                             r['t_collective'])*1e6:.0f},\"{derived}\"",
                      flush=True)
    except Exception:  # noqa: BLE001
        print(f"roofline,ERROR,\"{traceback.format_exc()[-200:]}\"")


if __name__ == "__main__":
    main()
