"""Observability overhead benchmarks (ISSUE 6).

The span tracer instruments permanent hot paths (store commits, checkpoint
flushes, cache lookups, every process run), so its *disabled* cost is a
contract, not a hope. Two metrics:

  O1 disabled overhead  — cost of a `with span():` block with REPRO_TRACE
                          off (the shared no-op singleton), scaled by the
                          spans-per-process count of a real traced run and
                          compared against the per-process engine time
                          (engine_bench B1 methodology). MUST stay < 5%.
  O2 enabled overhead   — the same engine throughput run with tracing +
                          timeline persistence on, as a ratio over the
                          disabled run. Reported (not asserted): tracing
                          is opt-in, you pay only when you ask.
  O3 disabled faults    — cost of a disabled chaos `fault_point()` call
                          (docs/chaos.md). The seams sit on the same
                          hot paths as the tracer's spans, so the same
                          < 5% contract applies (asserted in --smoke
                          against the per-process engine time at the
                          tracer's spans-per-process density).

Usage:
    python benchmarks/obs_bench.py                # full N, prints json
    python benchmarks/obs_bench.py -o BENCH_obs.json
    python benchmarks/obs_bench.py --smoke        # small N + the 5% bar
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from repro.observability import metrics, trace  # noqa: E402
from repro.observability.timeline import load_spans  # noqa: E402


def bench_disabled_span_cost(n: int = 200_000) -> float:
    """Per-call cost (seconds) of a disabled `with span():` block."""
    trace.disable()
    span = trace.span
    # warm-up + measurement; the block body is empty so this is pure
    # tracer dispatch: one function call + one no-op context manager
    for _ in range(1000):
        with span("warm"):
            pass
    t0 = time.perf_counter()
    for _ in range(n):
        with span("x", pk=1):
            pass
    return (time.perf_counter() - t0) / n


def bench_disabled_fault_point_cost(n: int = 200_000) -> float:
    """Per-call cost (seconds) of a disabled chaos fault_point()."""
    from repro.chaos import faults

    faults.deactivate()
    fault_point = faults.fault_point
    for _ in range(1000):
        fault_point("store.commit.pre")
    t0 = time.perf_counter()
    for _ in range(n):
        fault_point("store.commit.pre")
    return (time.perf_counter() - t0) / n


def _engine_run(n_processes: int) -> float:
    """Per-process wall time of the B1-style engine throughput run."""
    import engine_bench

    r = engine_bench.bench_engine_throughput(n_processes=n_processes,
                                             slots=100)
    return r["us_per_call"] / 1e6


def count_spans_per_process() -> int:
    """How many spans one traced WorkChain run emits (from its persisted
    timeline — the same data `repro process report` renders)."""
    import engine_bench

    trace.enable()
    try:
        runner, store = engine_bench._fresh_runner(slots=10)
        Noop = engine_bench._NoopChain.get()
        from repro.core import Int

        async def main():
            h = runner.submit(Noop, {"n": Int(1)})
            await h.process.wait_done()
            return h.pk

        pk = runner.loop.run_until_complete(main())
        return len(load_spans(store, pk))
    finally:
        trace.disable()


def run_all(n_processes: int) -> dict:
    span_cost = bench_disabled_span_cost()
    fault_cost = bench_disabled_fault_point_cost()
    spans_per_proc = count_spans_per_process()

    trace.disable()
    metrics.reset_registry()
    t_disabled = _engine_run(n_processes)

    trace.enable()
    metrics.reset_registry()
    try:
        t_enabled = _engine_run(n_processes)
    finally:
        trace.disable()

    # the contract: even if every span of a traced run stayed instrumented
    # on the hot path, the disabled-tracer dispatch cost per process is a
    # negligible fraction of what the engine spends per process
    disabled_pct = span_cost * spans_per_proc / t_disabled * 100
    # same density assumption for the chaos seams: ~spans-per-process
    # fault points on the hot path (in truth there are fewer)
    fault_pct = fault_cost * spans_per_proc / t_disabled * 100
    return {
        "disabled_span_ns": round(span_cost * 1e9, 1),
        "disabled_fault_point_ns": round(fault_cost * 1e9, 1),
        "disabled_fault_overhead_pct": round(fault_pct, 4),
        "spans_per_process": spans_per_proc,
        "engine_us_per_process_disabled": round(t_disabled * 1e6, 1),
        "engine_us_per_process_enabled": round(t_enabled * 1e6, 1),
        "disabled_overhead_pct": round(disabled_pct, 4),
        "enabled_overhead_ratio": round(t_enabled / t_disabled, 3),
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("-o", "--out", default=None,
                    help="json file to write results into")
    ap.add_argument("--smoke", action="store_true",
                    help="small N + assert the <5%% disabled-overhead bar")
    ap.add_argument("-n", "--processes", type=int, default=200)
    args = ap.parse_args(argv)

    n = 60 if args.smoke else args.processes
    results = run_all(n)
    print(json.dumps(results, indent=1))

    if args.smoke:
        assert results["spans_per_process"] >= 3, \
            f"traced run recorded only {results['spans_per_process']} spans"
        pct = results["disabled_overhead_pct"]
        assert pct < 5.0, \
            f"O1 bar: disabled tracer costs {pct:.2f}% of engine time (>=5%)"
        fpct = results["disabled_fault_overhead_pct"]
        assert fpct < 5.0, \
            f"O3 bar: disabled fault points cost {fpct:.2f}% of engine time"
        print(f"smoke OK: disabled overhead {pct:.4f}% "
              f"({results['spans_per_process']} spans/process, "
              f"{results['disabled_span_ns']}ns/span); disabled "
              f"fault_point {results['disabled_fault_point_ns']}ns "
              f"({fpct:.4f}%)")

    if args.out:
        with open(args.out, "w") as fh:
            json.dump(results, fh, indent=1, sort_keys=True)
            fh.write("\n")
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
