"""Analytic FLOP/byte models used to correct XLA cost analysis.

XLA's HloCostAnalysis counts a ``lax.scan`` body ONCE, not x trip-count.
Three scan levels exist in this codebase:

1. the LAYER scan (dense/moe/vlm/audio stacks) — corrected by lowering
   unrolled L=2/L=4 cells and extrapolating linearly (launch/dryrun.py);
2. the KV-BLOCK scan inside chunked attention — corrected here with
   closed-form matmul counts (the lowered program executes every block,
   masked or not — masking waste is part of the *executed* number and is
   one of the §Perf findings);
3. the TIME/CHUNK scans of the recurrent families (RG-LRU, mLSTM, sLSTM)
   — corrected here analytically.

``model_flops`` is the *useful* figure (6·N_active·D convention +
mask-aware attention), used for the MODEL/HLO ratio.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

from repro.models.common import ModelConfig
from repro.models.registry import ShapeCell


def _train_factor(cfg: ModelConfig) -> float:
    """fwd(1) + bwd(2) + remat recompute (policy-dependent)."""
    if cfg.remat_policy in ("nothing_saveable", "none"):
        return 4.0
    if cfg.remat_policy.startswith("dots"):
        return 3.0
    return 3.0


def _pass_factor(cfg: ModelConfig, cell: ShapeCell) -> float:
    return _train_factor(cfg) if cell.kind == "train" else 1.0


# ---------------------------------------------------------------------------
# Attention score/combine flops (the part living inside kv-block scans)
# ---------------------------------------------------------------------------

def _score_flops(b, s_q, s_kv, heads, hd, frac=1.0):
    """qk^T + p·v matmuls: 2 x (2·B·Sq·Skv·H·hd) x live fraction."""
    return 4.0 * b * s_q * s_kv * heads * hd * frac


def attn_executed_flops(cfg: ModelConfig, cell: ShapeCell) -> float:
    """Attention score flops the lowered program EXECUTES (chunked path
    computes every block and masks), whole model, fwd x pass factor."""
    b = cell.global_batch
    s = cell.seq_len
    h, hd = cfg.num_heads, cfg.hd
    pf = _pass_factor(cfg, cell)
    if cell.kind == "decode":
        return 0.0   # decode attention is not scanned; HLO counts it
    if cfg.family == "audio":
        t = cfg.num_frames
        enc = cfg.encoder_layers or cfg.num_layers
        per_enc = _score_flops(b, t, t, h, hd)
        per_dec = _score_flops(b, s, s, h, hd) + _score_flops(b, s, t, h, hd)
        return (enc * per_enc + cfg.num_layers * per_dec) * pf
    if cfg.family == "hybrid":
        kinds = _rg_kinds(cfg)
        n_attn = sum(1 for k in kinds if k == "attn")
        return n_attn * _score_flops(b, s, s, h, hd) * pf
    if cfg.family == "ssm":
        return 0.0   # handled by mlstm/slstm corrections
    return cfg.num_layers * _score_flops(b, s, s, h, hd) * pf


def attn_useful_flops(cfg: ModelConfig, cell: ShapeCell) -> float:
    """Mask-aware useful attention flops (causal half / local window)."""
    b = cell.global_batch
    s = cell.seq_len
    h, hd = cfg.num_heads, cfg.hd
    pf = 3.0 if cell.kind == "train" else 1.0   # useful: no remat recompute
    if cell.kind == "decode":
        kv = min(cfg.local_window, s) if (cfg.family == "hybrid") else s
        if cfg.family == "ssm":
            return 0.0
        if cfg.family == "hybrid":
            kinds = _rg_kinds(cfg)
            n_attn = sum(1 for k in kinds if k == "attn")
            return n_attn * _score_flops(b, 1, kv, h, hd)
        layers = cfg.num_layers
        extra = 0.0
        if cfg.family == "audio":
            extra = layers * _score_flops(b, 1, cfg.num_frames, h, hd)
        return layers * _score_flops(b, 1, s, h, hd) + extra
    if cfg.family == "audio":
        t = cfg.num_frames
        enc = cfg.encoder_layers or cfg.num_layers
        per_enc = _score_flops(b, t, t, h, hd)
        per_dec = _score_flops(b, s, s, h, hd, 0.5) + \
            _score_flops(b, s, t, h, hd)
        return (enc * per_enc + cfg.num_layers * per_dec) * pf
    if cfg.family == "hybrid":
        kinds = _rg_kinds(cfg)
        n_attn = sum(1 for k in kinds if k == "attn")
        w = min(cfg.local_window or s, s)
        frac = min(1.0, w / s)  # local window live fraction (approx)
        return n_attn * _score_flops(b, s, s, h, hd, frac) * pf
    if cfg.family == "ssm":
        return mlstm_flops(cfg, cell, useful=True) + slstm_flops(cfg, cell)
    return cfg.num_layers * _score_flops(b, s, s, h, hd, 0.5) * pf


def attn_executed_bytes(cfg: ModelConfig, cell: ShapeCell) -> float:
    """HBM traffic of the chunked-attention scan the HLO count misses:
    per kv-block step the scan re-reads q and reads+writes the fp32
    (m, l, acc) carry. This is the dominant *memory-term* pathology of
    flash-in-XLA vs a fused Pallas kernel (carry lives in VMEM there)."""
    if cell.kind == "decode" or cfg.family == "ssm":
        return 0.0
    b = cell.global_batch
    s = cell.seq_len
    h, hd = cfg.num_heads, cfg.hd
    blk = cfg.attn_kv_block
    pf = _pass_factor(cfg, cell)

    def per_layer(s_q, s_kv):
        nblk = max(1, s_kv // max(1, min(blk, s_kv)))
        q_bytes = b * s_q * h * hd * 2
        carry = b * s_q * h * hd * 4 + 2 * b * s_q * h * 4   # acc + m,l fp32
        kv_bytes = b * s_kv * cfg.kv_heads_eff * hd * 2 * 2
        return nblk * (q_bytes + 2 * carry) + kv_bytes

    if cfg.family == "audio":
        t = cfg.num_frames
        enc = cfg.encoder_layers or cfg.num_layers
        total = enc * per_layer(t, t) + \
            cfg.num_layers * (per_layer(s, s) + per_layer(s, t))
    elif cfg.family == "hybrid":
        n_attn = sum(1 for k in _rg_kinds(cfg) if k == "attn")
        total = n_attn * per_layer(s, s)
    else:
        total = cfg.num_layers * per_layer(s, s)
    return total * pf


# ---------------------------------------------------------------------------
# Recurrent-family in-scan corrections
# ---------------------------------------------------------------------------

def _rg_kinds(cfg: ModelConfig):
    pattern = cfg.block_pattern or ("rglru", "rglru", "attn")
    return [pattern[i % len(pattern)] for i in range(cfg.num_layers)]


def rglru_flops(cfg: ModelConfig, cell: ShapeCell) -> float:
    """Elementwise recurrence ops inside the blocked time scan."""
    if cfg.family != "hybrid" or cell.kind == "decode":
        return 0.0
    b, s = cell.global_batch, cell.seq_len
    dr = cfg.d_rnn or cfg.d_model
    n_rec = sum(1 for k in _rg_kinds(cfg) if k == "rglru")
    # ~10 elementwise ops per element per associative-scan level (log2 256=8)
    per_layer = 10.0 * b * s * dr * 8
    return n_rec * per_layer * _pass_factor(cfg, cell)


def mlstm_flops(cfg: ModelConfig, cell: ShapeCell, useful=False) -> float:
    if cfg.family != "ssm" or cell.kind == "decode":
        return 0.0
    from repro.models.xlstm import d_inner, slstm_positions
    b, s = cell.global_batch, cell.seq_len
    di = d_inner(cfg)
    h = cfg.num_heads
    hd = di // h
    L = min(cfg.mlstm_chunk, s)
    nc = max(1, s // L)
    n_m = cfg.num_layers - len(slstm_positions(cfg))
    # per chunk: qk^T (2 L^2 hd), att.v (2 L^2 hd), kv update (2 L hd^2),
    # h_inter (2 L hd^2)
    per_chunk = b * h * (4.0 * L * L * hd + 4.0 * L * hd * hd)
    pf = (3.0 if useful else _train_factor(cfg)) if cell.kind == "train" \
        else 1.0
    return n_m * nc * per_chunk * pf


def slstm_flops(cfg: ModelConfig, cell: ShapeCell) -> float:
    if cfg.family != "ssm" or cell.kind == "decode":
        return 0.0
    from repro.models.xlstm import slstm_positions
    b, s = cell.global_batch, cell.seq_len
    d = cfg.d_model
    hd = d // cfg.num_heads
    n_s = len(slstm_positions(cfg))
    # 4 recurrent block-diagonal matvecs per step: 4 x 2 x d x hd
    per_layer = 8.0 * b * s * d * hd
    return n_s * per_layer * _pass_factor(cfg, cell)


def inner_scan_flop_correction(cfg: ModelConfig, cell: ShapeCell) -> float:
    """Everything the per-layer HLO numbers miss inside in-layer scans."""
    blk = min(cfg.attn_kv_block, cell.seq_len)
    nblk = max(1, cell.seq_len // max(1, blk))
    frac = (nblk - 1) / nblk if nblk > 1 else 0.0
    total = attn_executed_flops(cfg, cell) * frac
    total += rglru_flops(cfg, cell)
    # mlstm chunk scan: HLO saw one chunk of nc
    ml = mlstm_flops(cfg, cell)
    nc = max(1, cell.seq_len // max(1, min(cfg.mlstm_chunk, cell.seq_len)))
    total += ml * (nc - 1) / nc if nc > 1 else 0.0
    total += slstm_flops(cfg, cell)
    return total


# ---------------------------------------------------------------------------
# Useful model flops (the MODEL_FLOPS convention)
# ---------------------------------------------------------------------------

def model_flops(cfg: ModelConfig, cell: ShapeCell, params_active: int
                ) -> float:
    tokens = (cell.global_batch if cell.kind == "decode"
              else cell.global_batch * cell.seq_len)
    mult = 6.0 if cell.kind == "train" else 2.0
    return mult * params_active * tokens + attn_useful_flops(cfg, cell)
