"""Quickstart: the paper's own API tour in one script.

    PYTHONPATH=src python examples/quickstart.py

Covers: calcfunction/workfunction provenance (figs. 1-2), the WorkChain
outline DSL (fizzbuzz, listing 9), ToContext subprocesses, exit codes,
the ProcessBuilder + engine.launch API (run/run_get_node on a builder,
port serializers wrapping raw python), and querying the resulting
provenance graph.
"""

import sys

sys.path.insert(0, "src")

from repro.core import (
    Int, Str, ToContext, WorkChain, calcfunction, if_, while_, workfunction,
)
from repro.engine.launch import run, run_get_node
from repro.engine.runner import Runner, set_default_runner
from repro.provenance import NodeType, QueryBuilder, configure_store


# --- calculation functions (paper listing 6) --------------------------------

@calcfunction
def add(a, b):
    return a + b


@calcfunction
def multiply(a, b):
    return a * b


# --- a work function orchestrating them (listing 8) --------------------------

@workfunction
def add_multiply(x, y, z):
    total = add(x, y)
    return multiply(total, z)


# --- the fizzbuzz work chain (listing 9) --------------------------------------

class FizzBuzzWorkChain(WorkChain):
    @classmethod
    def define(cls, spec):
        super().define(spec)
        spec.input("n_max", valid_type=Int, serializer=Int, default=Int(15))
        spec.output("summary", valid_type=Str)
        spec.outline(
            cls.initialize_to_zero,
            while_(cls.is_less_than_n_max)(
                if_(cls.is_multiple_of_three_and_five)(
                    cls.report_fizz_buzz,
                ).elif_(cls.is_multiple_of_three)(
                    cls.report_fizz,
                ).elif_(cls.is_multiple_of_five)(
                    cls.report_buzz,
                ).else_(
                    cls.report_n,
                ),
                cls.increment_by_one,
            ),
            cls.finalize,
        )

    def initialize_to_zero(self):
        self.ctx.n = 0
        self.ctx.words = []

    def is_less_than_n_max(self):
        return self.ctx.n <= int(self.inputs["n_max"].value)

    def is_multiple_of_three_and_five(self):
        return self.ctx.n % 15 == 0

    def is_multiple_of_three(self):
        return self.ctx.n % 3 == 0

    def is_multiple_of_five(self):
        return self.ctx.n % 5 == 0

    def report_fizz_buzz(self):
        self.ctx.words.append("fizzbuzz")

    def report_fizz(self):
        self.ctx.words.append("fizz")

    def report_buzz(self):
        self.ctx.words.append("buzz")

    def report_n(self):
        self.ctx.words.append(str(self.ctx.n))

    def increment_by_one(self):
        self.ctx.n += 1

    def finalize(self):
        self.report("counted to %d", self.ctx.n - 1)
        self.out("summary", Str(" ".join(self.ctx.words)))


# --- a parent chain waiting on a child (listings 11/16) -----------------------

class ChildWorkChain(WorkChain):
    @classmethod
    def define(cls, spec):
        super().define(spec)
        spec.input("a", valid_type=Int)
        spec.output("squared", valid_type=Int)
        spec.outline(cls.run_step)

    def run_step(self):
        self.report("running the ChildWorkChain")
        self.out("squared", multiply(self.inputs["a"], self.inputs["a"]))


class ParentWorkChain(WorkChain):
    @classmethod
    def define(cls, spec):
        super().define(spec)
        spec.expose_inputs(ChildWorkChain)
        spec.output("result", valid_type=Int)
        spec.outline(cls.run_child, cls.collect)

    def run_child(self):
        child = self.submit(ChildWorkChain,
                            **self.exposed_inputs(ChildWorkChain))
        return ToContext(child=child)

    def collect(self):
        self.out("result", self.ctx.child.outputs["squared"])


def main():
    store = configure_store("examples_out/quickstart.db")
    runner = Runner(store=store)
    set_default_runner(runner)

    print("== process functions ==")
    result = add_multiply(Int(1), Int(2), Int(3))
    print(f"add_multiply(1, 2, 3) = {result.value}")

    print("\n== fizzbuzz work chain (builder + launch API) ==")
    # the builder mirrors the port tree; a raw 15 is serialized to Int(15)
    # on assignment, so provenance still records a proper data node
    builder = FizzBuzzWorkChain.get_builder()
    builder.n_max = 15
    builder.metadata.label = "quickstart-fizzbuzz"
    outputs = run(builder)
    print(outputs["summary"].value)

    print("\n== parent/child with ToContext ==")
    outputs, proc = run_get_node(ParentWorkChain, a=Int(12))
    print(f"12^2 = {outputs['result'].value}")

    print("\n== provenance graph ==")
    qb = QueryBuilder(store)
    for nt in (NodeType.CALC_FUNCTION, NodeType.WORK_FUNCTION,
               NodeType.WORK_CHAIN, NodeType.DATA):
        print(f"  {nt.value:24s} {qb.__class__(store).nodes(nt).count()} nodes")
    logs = store.get_logs(proc.pk)
    print(f"  reports on last chain: {[l['message'] for l in logs]}")


if __name__ == "__main__":
    main()
