"""High-throughput mode: the daemon (broker + worker pool) chewing through
a batch of training jobs with injected faults — the paper's headline
deployment (fig. 4/5).

    PYTHONPATH=src python examples/high_throughput.py --jobs 8 --workers 2
    PYTHONPATH=src python examples/high_throughput.py --crash   # kill workers mid-run

With --crash, workers hard-exit every ~2s until the supervisor has
restarted four of them; jobs still finish because (a) the broker requeues
un-acked tasks when heartbeats stop, and (b) each process resumes from its
last persisted checkpoint on whichever worker picks it up.

With --cached-rerun, the whole batch is submitted a second time after the
first pass finishes: the daemon workers (which inherit REPRO_CACHING from
this process) resolve every job against the provenance cache, clone the
outputs and never touch the scheduler — the warm pass completes in
seconds regardless of job size.
"""

import argparse
import json
import os
import sys
import time

sys.path.insert(0, "src")

from repro.calcjobs import TPUTrainJob
from repro.core import Dict
from repro.engine.daemon import Daemon
from repro.provenance.store import NodeType, QueryBuilder, configure_store

TERMINAL = ("finished", "excepted", "killed")


def submit_batch(daemon, n_jobs):
    pks = []
    for i in range(n_jobs):
        # one builder per job: the discoverable launch surface — inputs
        # validate at assignment, before anything touches the queue
        builder = TPUTrainJob.get_builder()
        builder.config = Dict({
            "arch": "qwen2-0.5b", "steps": 3, "batch": 2, "seq": 32,
            "seed": i, "lr": 1e-3})
        builder.metadata.label = f"ht-job-{i}"
        pks.append(daemon.submit(builder))
    return pks


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--jobs", type=int, default=6)
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--crash", action="store_true")
    ap.add_argument("--cached-rerun", action="store_true",
                    help="resubmit the batch after it finishes; every job "
                         "should be served from the provenance cache")
    ap.add_argument("--workdir", default="examples_out/daemon")
    args = ap.parse_args()

    if args.cached_rerun:
        # workers inherit the environment at spawn time
        os.environ["REPRO_CACHING"] = "TPUTrainJob"

    daemon = Daemon(args.workdir, workers=args.workers, slots=16,
                    crash_after=2.0 if args.crash else None)
    daemon.start()
    print(f"daemon up: broker {daemon.host}:{daemon.port}, "
          f"{args.workers} workers")

    t0 = time.time()
    pks = submit_batch(daemon, args.jobs)
    print(f"submitted {args.jobs} TPUTrainJobs: pks={pks}")

    store = configure_store(daemon.store_path)
    restarts = 0
    while True:
        states = {pk: (store.get_node(pk) or {}).get("process_state")
                  for pk in pks}
        done = sum(s in TERMINAL for s in states.values())
        r = daemon.supervise()
        if r:
            restarts += r
            print(f"  [supervisor] restarted {r} dead worker(s)")
            if restarts >= 4:
                daemon.crash_after = None   # let replacements live
        print(f"  {done}/{len(pks)} done "
              f"({time.time()-t0:.0f}s, {restarts} worker restarts)")
        if done == len(pks):
            break
        time.sleep(1.0)

    print("\n== results ==")
    ok = 0
    for pk in pks:
        node = store.get_node(pk)
        ok += node["exit_status"] == 0
        print(f"  pk={pk}: {node['process_state']} "
              f"exit={node['exit_status']}")
    print(f"\n{ok}/{len(pks)} finished ok in {time.time()-t0:.1f}s "
          f"with {restarts} worker crashes survived")
    qb = QueryBuilder(store)
    print(f"provenance: {qb.nodes(NodeType.CALC_JOB).count()} calcjobs, "
          f"{QueryBuilder(store).nodes(NodeType.DATA).count()} data nodes")

    if args.cached_rerun:
        print("\n== cached second pass ==")
        t1 = time.time()
        pks2 = submit_batch(daemon, args.jobs)
        while True:
            done = sum((store.get_node(pk) or {}).get("process_state")
                       in TERMINAL for pk in pks2)
            daemon.supervise()
            if done == len(pks2):
                break
            time.sleep(0.2)
        t_warm = time.time() - t1
        hits = 0
        for pk in pks2:
            node = store.get_node(pk)
            attrs = json.loads(node.get("attributes") or "{}")
            hits += "cached_from" in attrs
        print(f"{len(pks2)} jobs finished in {t_warm:.1f}s "
              f"(first pass: {time.time()-t0-t_warm:.1f}s); "
              f"{hits}/{len(pks2)} served from cache")

    daemon.stop()


if __name__ == "__main__":
    main()
