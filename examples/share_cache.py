"""Two-profile cache sharing: export results from profile A, import into
profile B, and relaunch — B reuses A's computed results without running
anything (docs/archive.md walkthrough).

    PYTHONPATH=src python examples/share_cache.py

Profile A ("the collaborator who already ran the campaign") executes a
small sweep of deterministic calculations and exports the finished-ok
subgraph as a provenance archive. Profile B (a fresh, empty store —
another machine, another user) imports the archive and submits the *same*
sweep with caching enabled: every process resolves to an imported node,
clones its outputs and records `cached_from` pointing at A's work.
"""

import json
import os
import sys
import time

sys.path.insert(0, "src")

import numpy as np

from repro.caching import enable_caching
from repro.core import ArrayData, Int, Process, ProcessSpec
from repro.engine.launch import run_get_node
from repro.engine.runner import Runner, set_default_runner
from repro.provenance import (
    NodeType, ProvenanceStore, configure_store, export_archive,
    import_archive,
)

OUT_DIR = "examples_out"


class PowerIterate(Process):
    """A deterministic 'simulation': dominant eigenvalue of a seed-derived
    matrix by power iteration (stand-in for a real calculation)."""

    NODE_TYPE = NodeType.CALC_FUNCTION

    @classmethod
    def define(cls, spec: ProcessSpec) -> None:
        super().define(spec)
        spec.input("seed", valid_type=Int, serializer=Int)
        spec.input("size", valid_type=Int, serializer=Int, default=Int(96))
        spec.input("iters", valid_type=Int, serializer=Int, default=Int(150))
        spec.output("eigenvalue", valid_type=ArrayData)
        spec.output("vector", valid_type=ArrayData)

    async def run(self):
        n = self.inputs["size"].value
        rng = np.random.default_rng(self.inputs["seed"].value)
        mat = rng.standard_normal((n, n))
        mat = mat @ mat.T  # symmetric, real spectrum
        vec = np.ones(n) / np.sqrt(n)
        for _ in range(self.inputs["iters"].value):
            vec = mat @ vec
            vec /= np.linalg.norm(vec)
        self.out("eigenvalue", ArrayData(vec @ mat @ vec))
        self.out("vector", ArrayData(vec))


def run_sweep(seeds: list[int]) -> tuple[list, float]:
    t0 = time.perf_counter()
    nodes = [run_get_node(PowerIterate, seed=s).node for s in seeds]
    return nodes, time.perf_counter() - t0


def main() -> None:
    os.makedirs(OUT_DIR, exist_ok=True)
    profile_a = os.path.join(OUT_DIR, "share_a.db")
    profile_b = os.path.join(OUT_DIR, "share_b.db")
    archive = os.path.join(OUT_DIR, "share_results.zip")
    for path in (profile_a, profile_b, archive):
        if os.path.exists(path):
            os.remove(path)
    seeds = list(range(12))

    # --- profile A: compute the sweep, export the results ------------------
    store_a = configure_store(profile_a)
    set_default_runner(Runner(store=store_a))
    nodes_a, t_compute = run_sweep(seeds)
    manifest = export_archive(store_a, archive,
                              [n.pk for n in nodes_a], source=profile_a)
    print(f"[A] computed {len(seeds)} calculations in {t_compute:.2f}s, "
          f"exported {manifest['nodes']} node(s) "
          f"({manifest['payload_files']} array payload(s)) -> {archive}")

    # --- profile B: fresh store, import, relaunch with caching ------------
    store_b = configure_store(profile_b)
    set_default_runner(Runner(store=store_b))
    result = import_archive(store_b, archive)
    print(f"[B] imported {result.nodes_imported} node(s), "
          f"{result.links_imported} link(s)")

    with enable_caching(PowerIterate):
        nodes_b, t_cached = run_sweep(seeds)

    hits = 0
    for node in nodes_b:
        attrs = json.loads(
            (store_b.get_node(node.pk) or {}).get("attributes") or "{}")
        if "cached_from" in attrs:
            src = store_b.get_node(attrs["cached_from_pk"])
            assert src is not None and src["process_state"] == "finished"
            hits += 1
    print(f"[B] relaunched the sweep with caching: {t_cached:.2f}s, "
          f"{hits}/{len(seeds)} cache hits against imported nodes "
          f"({t_compute / max(t_cached, 1e-9):.1f}x faster than computing)")
    if hits != len(seeds):
        sys.exit("expected every relaunch to hit the imported cache")


if __name__ == "__main__":
    main()
