"""End-to-end driver: a PretrainWorkChain training a language model under
the engine, with checkpoint/restart, NaN error handling and provenance.

    PYTHONPATH=src python examples/train_lm.py                 # ~10M model
    PYTHONPATH=src python examples/train_lm.py --preset 110m   # full demo
    PYTHONPATH=src python examples/train_lm.py --steps 300

The chain trains in CHUNKS: every outline step runs `chunk_steps` optimizer
steps, then checkpoints (model state via the sharded tensor checkpointer,
engine state via the process checkpoint, data cursor inside the context) —
kill the process at any point and rerun with --resume <pk> to continue from
the last chunk boundary. A NaN loss aborts the chunk with exit code 310 and
the chain restarts from the last good checkpoint with a lower LR.
"""

import argparse
import math
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import Dict, Float, Int, WorkChain, while_
from repro.engine.launch import run_get_node
from repro.engine.runner import Runner, set_default_runner
from repro.models.registry import build
from repro.provenance import configure_store
from repro.training import checkpoint as ckpt
from repro.training.data import DataConfig, TokenStream
from repro.training.optim import OptimConfig
from repro.training.train_step import (
    TrainConfig, init_train_state, make_train_step,
)

PRESETS = {
    "tiny": dict(num_layers=4, d_model=256, num_heads=4, num_kv_heads=2,
                 d_ff=704, vocab_size=8192),
    "110m": {},   # the aiida-demo-110m config as-is
}


class PretrainWorkChain(WorkChain):
    """Trains in checkpointed chunks; recovers from NaN by lowering LR."""

    @classmethod
    def define(cls, spec):
        super().define(spec)
        spec.input("preset", valid_type=Dict, serializer=Dict,
                   help="model-config overrides applied to the base config")
        spec.input("total_steps", valid_type=Int, serializer=Int,
                   default=lambda: Int(60))
        spec.input("chunk_steps", valid_type=Int, serializer=Int,
                   default=lambda: Int(20))
        spec.input("lr", valid_type=Float, serializer=Float,
                   default=lambda: Float(3e-3))
        spec.input("ckpt_dir", valid_type=Dict, serializer=Dict,
                   default=lambda: Dict({"dir": ""}), required=False)
        spec.output("final_metrics", valid_type=Dict)
        spec.exit_code(310, "ERROR_NAN_LOSS", "loss diverged to NaN")
        spec.exit_code(320, "ERROR_NO_PROGRESS",
                       "loss failed to improve across restarts")
        spec.outline(
            cls.setup,
            while_(cls.not_done)(
                cls.train_chunk,
            ),
            cls.finalize,
        )

    # -- helpers (jit cache lives on the instance, not the checkpoint) -----
    def _ensure_runtime(self):
        if hasattr(self, "_step_fn"):
            return
        preset = dict(self.inputs["preset"].value)
        cfg = get_config("aiida-demo-110m").replace(**preset)
        self._bundle = build(cfg)
        ocfg = OptimConfig(lr=self.ctx.lr,
                           warmup_steps=10,
                           total_steps=int(self.inputs["total_steps"].value))
        tcfg = TrainConfig(optim=ocfg)
        self._step_fn = jax.jit(make_train_step(self._bundle, tcfg),
                                donate_argnums=(0,))
        self._data = TokenStream(DataConfig(
            vocab_size=cfg.vocab_size, seq_len=self.ctx.seq_len,
            batch_size=self.ctx.batch, seed=17))
        if self.ctx.data_cursor is not None:
            self._data.load_state_dict(self.ctx.data_cursor)
        ckdir = self.ctx.ckpt_dir
        step = ckpt.latest_step(ckdir)
        if step is not None:
            target = init_train_state(self._bundle, tcfg,
                                      jax.random.PRNGKey(0))
            self._train_state = ckpt.restore_checkpoint(ckdir, target=target)
            self.report("restored model checkpoint at step %d", step)
        else:
            self._train_state = init_train_state(self._bundle, tcfg,
                                           jax.random.PRNGKey(0))
        self._tcfg = tcfg

    # -- outline ------------------------------------------------------------
    def setup(self):
        self.ctx.step = 0
        self.ctx.losses = []
        self.ctx.lr = float(self.inputs["lr"].value)
        self.ctx.nan_restarts = 0
        self.ctx.data_cursor = None
        self.ctx.seq_len = 128
        self.ctx.batch = 4
        self.ctx.ckpt_dir = (self.inputs["ckpt_dir"].value.get("dir")
                             or f"examples_out/ckpt_{self.pk}")
        self.report("training starts: %d steps in chunks of %d",
                    self.inputs["total_steps"].value,
                    self.inputs["chunk_steps"].value)

    def not_done(self):
        return self.ctx.step < int(self.inputs["total_steps"].value)

    def train_chunk(self):
        self._ensure_runtime()
        n = min(int(self.inputs["chunk_steps"].value),
                int(self.inputs["total_steps"].value) - self.ctx.step)
        t0 = time.time()
        for _ in range(n):
            batch = self._data.next_batch()
            self._train_state, metrics = self._step_fn(
                self._train_state, {k: jnp.asarray(v) for k, v in batch.items()})
        loss = float(metrics["loss"])
        dt = time.time() - t0
        self.ctx.step += n

        if math.isnan(loss) or math.isinf(loss):
            self.ctx.step -= n     # rewind: the chunk did not commit
            self.ctx.nan_restarts += 1
            if self.ctx.nan_restarts > 3:
                return self.exit_codes.ERROR_NO_PROGRESS
            self.ctx.lr /= 10.0
            del self._step_fn      # rebuild with the lower LR
            self.report("NaN at step %d! restarting chunk from last "
                        "checkpoint with lr=%.2e", self.ctx.step, self.ctx.lr)
            return None            # chunk re-runs from last good state

        # commit: loss history, data cursor, model checkpoint — this is the
        # restart point for both engine-level and tensor-level recovery
        self.ctx.losses.append(loss)
        self.ctx.data_cursor = self._data.state_dict()
        ckpt.save_checkpoint(self.ctx.ckpt_dir, self.ctx.step, self._train_state)
        self.report("step %d: loss=%.4f grad_norm=%.2f (%.1fs, %.1f tok/s)",
                    self.ctx.step, loss, float(metrics["grad_norm"]), dt,
                    n * self.ctx.batch * self.ctx.seq_len / dt)

    def finalize(self):
        self.report("done: %d steps, final loss %.4f",
                    self.ctx.step, self.ctx.losses[-1])
        self.out("final_metrics", Dict({
            "losses": self.ctx.losses,
            "final_loss": self.ctx.losses[-1],
            "steps": self.ctx.step,
            "nan_restarts": self.ctx.nan_restarts,
        }))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--chunk", type=int, default=20)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--resume", type=int, default=None,
                    help="pk of an interrupted chain to resume")
    args = ap.parse_args()

    store = configure_store("examples_out/train_lm.db")
    runner = Runner(store=store)
    set_default_runner(runner)

    if args.resume is not None:
        handle = runner.resume_from_checkpoint(args.resume)
        if handle is None:
            print(f"no checkpoint for pk={args.resume}")
            return
        runner.loop.run_until_complete(handle.process.wait_done())
        proc = handle.process
    else:
        # builder + launch API: raw python scalars/dicts are wrapped by
        # the port serializers, so provenance stays complete without
        # Int(...)/Dict(...) boilerplate at every call site
        builder = PretrainWorkChain.get_builder()
        builder.preset = PRESETS[args.preset]
        builder.total_steps = args.steps
        builder.chunk_steps = args.chunk
        builder.lr = args.lr
        builder.metadata.label = f"train-lm-{args.preset}"
        outputs, proc = run_get_node(builder)

    print(f"\nstate={proc.state.value} exit={proc.exit_code}")
    for log in store.get_logs(proc.pk):
        print("  [report]", log["message"])
    if "final_metrics" in proc.outputs:
        m = proc.outputs["final_metrics"].value
        print(f"loss: {m['losses'][0]:.3f} -> {m['final_loss']:.3f} "
              f"over {m['steps']} steps")


if __name__ == "__main__":
    main()
