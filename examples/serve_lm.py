"""Continuous-batching LM serving on a small model.

    PYTHONPATH=src python examples/serve_lm.py --batch 4 --requests 10

Front door is ``repro.configs.setup_devices`` (host-device forcing works
on CPU-only machines), then a :class:`~repro.serving.serve.BatchScheduler`
drives prefill + per-slot-position decode: requests of different prompt
lengths and budgets are co-batched, evicted on completion, and replaced
from the FIFO queue mid-flight. ``--decode-impl pallas`` routes the
decode inner product through the flash-decode kernel (interpreted off
TPU); ``--int8-kv`` quantises the KV cache.
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

import numpy as np

from repro.configs import get_config, setup_devices
from repro.models.registry import build
from repro.serving.serve import BatchScheduler, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--platform", default="cpu")
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument("--batch", type=int, default=4,
                    help="decode slots (micro-batch size)")
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--max-prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=24)
    ap.add_argument("--decode-impl", default="pallas",
                    choices=["direct", "pallas"])
    ap.add_argument("--int8-kv", action="store_true")
    args = ap.parse_args()

    devices = setup_devices(platform=args.platform, n_devices=args.devices)
    print(f"devices: {len(devices)}x {devices[0].platform}")

    import jax  # after setup_devices so the platform choice sticks

    cfg = get_config("aiida-demo-110m").replace(
        num_layers=4, d_model=256, num_heads=4, num_kv_heads=2, d_ff=704,
        vocab_size=8192, decode_impl=args.decode_impl,
        kv_cache_dtype="int8" if args.int8_kv else "bfloat16")
    bundle = build(cfg)
    params = bundle.init_params(jax.random.PRNGKey(0))

    max_len = args.max_prompt_len + args.new_tokens + 1
    sched = BatchScheduler(bundle, params, batch_size=args.batch,
                           max_len=max_len)

    # mixed-length prompts from a small length set (each distinct prompt
    # length compiles its own prefill; decode is one shared program)
    rng = np.random.default_rng(0)
    lengths = [args.max_prompt_len, args.max_prompt_len // 2]
    t0 = time.time()
    for rid in range(args.requests):
        n = lengths[rid % len(lengths)]
        sched.submit(Request(
            rid=rid,
            prompt=rng.integers(1, cfg.vocab_size, n).tolist(),
            max_new_tokens=args.new_tokens - (rid % 3) * 4))
    finished = sched.run()
    dt = time.time() - t0

    toks = sum(len(r.generated) for r in finished)
    print(f"served {len(finished)} requests through {args.batch} slots in "
          f"{dt:.2f}s ({toks} tokens, {toks/dt:.0f} tok/s, "
          f"decode_impl={args.decode_impl}, "
          f"kv={'int8' if args.int8_kv else 'bf16'})")
    for r in finished[:4]:
        print(f"  req {r.rid}: prompt {len(r.prompt):3d} tok -> "
              f"{len(r.generated):2d} new [{r.finish_reason}] "
              f"{r.generated[:8]} ...")


if __name__ == "__main__":
    main()
