"""Batched serving: prefill + continuous greedy decode on a small LM.

    PYTHONPATH=src python examples/serve_lm.py --batch 4 --new-tokens 24

Uses the same serve_step the decode_* dry-run cells lower for the 256-chip
mesh — here on CPU with a reduced model, demonstrating the KV cache, the
(optional) int8 cache quantisation, and tokens/s accounting.
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.registry import build
from repro.serving.serve import make_decode_step, make_prefill_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=24)
    ap.add_argument("--int8-kv", action="store_true")
    args = ap.parse_args()

    cfg = get_config("aiida-demo-110m").replace(
        num_layers=4, d_model=256, num_heads=4, num_kv_heads=2, d_ff=704,
        vocab_size=8192,
        kv_cache_dtype="int8" if args.int8_kv else "bfloat16")
    bundle = build(cfg)
    params = bundle.init_params(jax.random.PRNGKey(0))

    b, s = args.batch, args.prompt_len
    max_len = s + args.new_tokens + 1
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(1, cfg.vocab_size, (b, s)), jnp.int32)

    prefill = jax.jit(make_prefill_step(bundle))
    decode = jax.jit(make_decode_step(bundle), donate_argnums=(1,))

    cache = bundle.init_cache(b, max_len)
    t0 = time.time()
    tok, cache = prefill(params, {"tokens": prompts}, cache)
    tok.block_until_ready()
    t_prefill = time.time() - t0
    print(f"prefill: {b}x{s} tokens in {t_prefill*1e3:.0f}ms "
          f"({b*s/t_prefill:.0f} tok/s)")

    generated = [tok]
    t0 = time.time()
    for i in range(args.new_tokens - 1):
        tok, cache = decode(params, cache, tok, jnp.asarray(s + i))
        generated.append(tok)
    tok.block_until_ready()
    t_decode = time.time() - t0
    out = jnp.concatenate(generated, axis=1)
    print(f"decode: {args.new_tokens - 1} steps x batch {b} in "
          f"{t_decode*1e3:.0f}ms "
          f"({b*(args.new_tokens-1)/t_decode:.0f} tok/s)")
    kv = "int8" if args.int8_kv else "bf16"
    print(f"kv cache dtype: {kv}")
    for row in range(min(b, 2)):
        print(f"  sample {row}: {np.asarray(out[row])[:12].tolist()} ...")


if __name__ == "__main__":
    main()
